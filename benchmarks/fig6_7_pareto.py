"""Figs. 6-7: latency / area vs test-error Pareto frontiers — on the
mesh sweep engine.

Trains a sweep of circuit sizes in the LogicNets setting (N=1,L=1,S=0)
and the NeuraLUT setting (N=16,L=4,S=2), evaluates accuracy on synthetic
MNIST (pooled), and derives latency/area from the cost model.  The
reproduction claim: at matched accuracy NeuraLUT needs fewer circuit
layers => lower latency and smaller area-delay product.

``run`` drives the whole grid through ``repro.sweep.run_pareto_sweep``:
same-shape geometries train as ONE compiled padded-and-stacked program
(seeds x geometries on the unit axis), and frontier points stream out of
a ``CallbackTracker`` into the CSV as each group finishes — with cold
(compile) and warm (run) seconds reported separately so the BENCH
numbers are load-robust (the old per-point wall-clock folded the first
point's compile into its timing).

``run_sweep_bench`` is the gated perf suite ("sweep" section of
BENCH_kernels.json): the mesh engine vs a vendored copy of the
pre-engine sequential per-geometry loop on the same grid, both with the
cold/warm split, gated on total wall-clock speedup at equivalent
frontier results.  The loop pays one trace+compile per geometry; the
engine pays one per geometry GROUP and batches every unit into one
program — that compile amortization (plus mesh parallelism when devices
are available) is what the gate holds.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import emit
from repro.core import model as M
from repro.core.exec_plan import plan_subnet_exec
from repro.core.nl_config import NeuraLUTConfig
from repro.data import device_dataset, mnist_pooled
from repro.runtime.tracker import CallbackTracker
from repro.sweep import (SweepPoint, paper_sweep_points,
                         run_pareto_sweep)

# Back-compat alias: the paper grid now lives with the planner.
from repro.sweep.plan import PAPER_SWEEP as SWEEP  # noqa: F401


def _point_record(m: Dict) -> Tuple[str, str]:
    name = f"fig6_7/{m['point']}"
    derived = (f"err={m['err']:.4f};err_mean={m['err_mean']:.4f};"
               f"seeds={m['seeds']};latency_ns={m['latency_ns']:.1f};"
               f"luts={m['luts']:.0f};adp={m['area_delay']:.2e};"
               f"cold_s={m['cold_s']:.2f};warm_s={m['warm_s']:.2f}")
    return name, derived


def run(epochs: int = 10, n_train: int = 6000, seeds: int = 3) -> None:
    # One host materialization + H2D per (n, seed) per process: every
    # Pareto point reuses the device-resident buffers (ROADMAP "Data
    # pipeline host staging").
    xtr, ytr = device_dataset(mnist_pooled, n_train, seed=0)
    xte, yte = device_dataset(mnist_pooled, 1500, seed=1)

    # Stream each point into the CSV the moment its group's program
    # finishes — warm time is the group's run seconds, reported apart
    # from the compile (cold) seconds instead of folded into the first
    # point's wall-clock.
    def record(m, step, summary):
        if summary:
            return
        name, derived = _point_record(m)
        emit(name, m["warm_s"] * 1e6 / max(1, m["seeds"]), derived)

    result = run_pareto_sweep(
        paper_sweep_points(), xtr, ytr, xte, yte,
        seeds=tuple(range(seeds)), epochs=epochs, batch=256, lr=3e-3,
        tracker=CallbackTracker(record))

    frontier = {}
    for res in result.points:
        frontier.setdefault(res.point.tag, []).append(
            (res.err, res.est.latency_ns, res.est.luts,
             res.est.area_delay))

    # claim: best NeuraLUT point dominates comparable LogicNets point on
    # latency at comparable-or-better error
    ln_best = min(frontier["logicnets"], key=lambda p: p[0])
    nl_best = min(frontier["neuralut"], key=lambda p: p[0])
    emit("fig6_7/claim_latency_reduction", 0.0,
         f"neuralut_lat={nl_best[1]:.1f}ns_err={nl_best[0]:.3f};"
         f"logicnets_lat={ln_best[1]:.1f}ns_err={ln_best[0]:.3f};"
         f"speedup={ln_best[1]/nl_best[1]:.2f}x")
    emit("fig6_7/engine", 0.0,
         f"groups={len(result.groups)};devices={result.devices};"
         f"cold_s={result.cold_s:.2f};warm_s={result.warm_s:.2f}")


# ---------------------------------------------------------------------------
# Vendored pre-engine loop + the gated engine-vs-loop bench ("sweep")


def _loop_point(cfg: NeuraLUTConfig, xd, yd, xe, ye, *, seeds, epochs,
                batch, lr) -> Tuple[Dict[str, np.ndarray], float, float]:
    """One Pareto point the pre-engine way: ``train_neuralut_ensemble``'s
    exact schedule with per-point jit objects (vendored so the bench
    comparison survives the engine rewire), instrumented with an AOT
    cold/warm split: both of the point's programs (scanned epoch, eval)
    are ``lower().compile()``d up front so compile seconds are reported
    apart from run seconds.  Returns (history, cold_s, warm_s)."""
    import jax

    from repro.core.train import (_make_ensemble_epoch_fn, _make_eval_fn,
                                  _make_step_fn, init_ensemble)

    statics = M.model_static(cfg)
    n = xd.shape[0]
    batch = min(batch, n)
    steps_per_epoch = max(1, n // batch)

    t0 = time.perf_counter()
    step_fn = _make_step_fn(
        cfg, statics, lr=lr, weight_decay=1e-4,
        t0=epochs * steps_per_epoch,
        exec_plan=plan_subnet_exec(cfg, purpose="train", route=None))
    jepoch = _make_ensemble_epoch_fn(step_fn, n, steps_per_epoch, batch)
    eval_one = _make_eval_fn(cfg, statics)

    @jax.jit
    def eval_all(params, state, xe, ye):
        return jax.vmap(lambda p, s: eval_one(p, s, xe, ye))(params, state)

    params, state, opt, keys = init_ensemble(cfg, seeds, xd)
    ekeys0 = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
    cepoch = jepoch.lower(params, state, opt, ekeys0, xd, yd).compile()
    ceval = eval_all.lower(params, state, xe, ye).compile()
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    traces = {"loss": [], "test_acc": [], "test_acc_q": []}
    for ep in range(epochs):
        ekeys = jax.vmap(lambda k: jax.random.fold_in(k, ep))(keys)
        params, state, opt, mloss = cepoch(params, state, opt, ekeys,
                                           xd, yd)
        acc, acc_q = ceval(params, state, xe, ye)
        traces["loss"].append(mloss)
        traces["test_acc"].append(acc)
        traces["test_acc_q"].append(acc_q)
    hist = {k: np.asarray(jax.device_get(v), np.float64)
            for k, v in traces.items()}
    return hist, cold_s, time.perf_counter() - t0


def _bench_grid() -> List[SweepPoint]:
    """Compile-dominated grid: two geometry families (-> two engine
    programs), four hidden widths each.  The loop compiles every point;
    the engine compiles each family once.  The grid is the SAME in fast
    and full mode — the gated speedup is dominated by the compile-count
    ratio, so an identical grid keeps the CI smoke ratio comparable to
    the committed full-mode baseline (fast mode only trims epochs and
    data, which move the tiny warm component)."""
    def subnet(w):
        return SweepPoint(NeuraLUTConfig(
            name=f"sw-sub-{w}", in_features=196, layer_widths=(w, 10),
            num_classes=10, beta=2, fan_in=6, kind="subnet", depth=2,
            width=8, skip=2), tag="subnet")

    def linear(w):
        return SweepPoint(NeuraLUTConfig(
            name=f"sw-lin-{w}", in_features=196, layer_widths=(w, 10),
            num_classes=10, beta=2, fan_in=6, kind="linear", depth=1,
            width=1, skip=0), tag="linear")

    widths = (24, 20, 16, 12)
    return [subnet(w) for w in widths] + [linear(w) for w in widths]


def run_sweep_bench(fast: bool = False) -> Dict:
    """Gated "sweep" section: mesh engine vs vendored sequential loop on
    the same grid, same seeds, same schedule.  Gate metric ``speedup`` =
    loop total (cold+warm) over engine total; ``units_per_s`` = trained
    (point, seed) units per engine-second.  ``frontier_max_abs_err_delta``
    records the largest per-point |err_loop - err_engine| (0.0 when both
    paths compile identically; small f32-chaos drift across different
    program partitionings otherwise — see tests/test_sweep.py)."""
    from repro.launch.mesh import make_sweep_mesh

    points = _bench_grid()
    seeds = (0, 1)
    epochs = 2 if fast else 3
    batch = 256
    n_train = 1024 if fast else 2048
    lr = 3e-3

    xtr, ytr = device_dataset(mnist_pooled, n_train, seed=0)
    xte, yte = device_dataset(mnist_pooled, 512, seed=1)

    # Sequential per-geometry loop (the pre-engine path), cold/warm split.
    loop_cold = loop_warm = 0.0
    loop_err: Dict[str, float] = {}
    for pt in points:
        hist, cold_s, warm_s = _loop_point(
            pt.cfg, xtr, ytr, xte, yte, seeds=seeds, epochs=epochs,
            batch=batch, lr=lr)
        loop_cold += cold_s
        loop_warm += warm_s
        loop_err[pt.name] = float(1.0 - hist["test_acc_q"][-1].max())
        emit(f"sweep/loop_{pt.name}", (cold_s + warm_s) * 1e6,
             f"cold_s={cold_s:.2f};warm_s={warm_s:.2f};"
             f"err={loop_err[pt.name]:.4f}")

    # The engine: same grid, one compiled program per geometry group.
    mesh = make_sweep_mesh()
    result = run_pareto_sweep(
        points, xtr, ytr, xte, yte, seeds=seeds, epochs=epochs,
        batch=batch, lr=lr, mesh=mesh)
    err_delta = max(abs(loop_err[r.name] - r.err) for r in result.points)
    for g in result.groups:
        emit(f"sweep/engine_group{g.group.index}",
             (g.cold_s + g.warm_s) * 1e6,
             f"points={len(g.group.points)};units={g.group.stacked_units};"
             f"cold_s={g.cold_s:.2f};warm_s={g.warm_s:.2f}")

    loop_total = loop_cold + loop_warm
    mesh_total = result.total_s
    units = len(points) * len(seeds)
    summary = {
        "devices": result.devices,
        "groups": len(result.groups),
        "points": len(points),
        "units": units,
        "seeds": len(seeds),
        "epochs": epochs,
        "loop": {"cold_s": round(loop_cold, 3),
                 "warm_s": round(loop_warm, 3),
                 "total_s": round(loop_total, 3)},
        "mesh": {"cold_s": round(result.cold_s, 3),
                 "warm_s": round(result.warm_s, 3),
                 "total_s": round(mesh_total, 3)},
        "speedup": round(loop_total / mesh_total, 3),
        "units_per_s": round(units / mesh_total, 3),
        "frontier_max_abs_err_delta": round(err_delta, 4),
        "fast_mode": fast,
    }
    emit("sweep/engine_vs_loop", mesh_total * 1e6,
         f"devices={result.devices};groups={len(result.groups)};"
         f"units={units};speedup={summary['speedup']:.2f}x;"
         f"loop_s={loop_total:.1f};mesh_s={mesh_total:.1f};"
         f"err_delta={err_delta:.4f}")
    return summary


if __name__ == "__main__":
    run()
