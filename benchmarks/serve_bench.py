"""Closed-loop serving benchmark: offered load vs latency for the LUT engine.

A pool of closed-loop clients (each submits a single-sample request, waits
for the prediction, submits the next) drives ``repro.serve.LUTServeEngine``;
sweeping the client count sweeps offered load.  For each concurrency level
we report the engine's own metrics — p50/p95/p99 end-to-end latency,
achieved throughput, mean queue depth and batch occupancy — which together
form the repo's serving performance trajectory (EXPERIMENTS.md §Perf,
serving section).

The bundle is trained once, saved through the registry, and *loaded back*
before serving, so the bench also exercises the deploy path end to end and
verifies bit-exactness against the ``lut_infer.lut_forward`` oracle.

    PYTHONPATH=src python benchmarks/serve_bench.py --reduced

Emits CSV lines ``name,us_per_call,derived`` (benchmarks/common.py); the
us_per_call column carries the p50 request latency.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import tempfile
import threading
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import get_config
from repro.core import lut_infer as LI
from repro.core import model as M
from repro.core import truth_table as TT
from repro.core.train import train_neuralut
from repro.data import device_dataset, jsc_synthetic
from repro.serve import (LUTServeEngine, MultiTenantEngine, ServeBundle,
                         ServeMetrics, TableRegistry, Tenant,
                         TenantOverloaded, bundle_from_training)


def _train_bundle(arch: str, *, reduced: bool, epochs: int, registry_dir: str):
    cfg = get_config(arch, reduced=reduced)
    xtr, ytr = device_dataset(jsc_synthetic, 8000 if reduced else 20000,
                              seed=0)
    xte, yte = device_dataset(jsc_synthetic, 2000, seed=1)
    params, state, hist = train_neuralut(
        cfg, xtr, ytr, xte, yte, epochs=epochs, batch=256, lr=2e-3)
    statics = M.model_static(cfg)
    tables, packed = TT.convert_packed(cfg, params, state, statics)
    bundle = bundle_from_training(
        cfg, params, tables, statics, packed_tables=packed,
        meta={"train_acc_q": float(hist["test_acc_q"][-1])})
    reg = TableRegistry(registry_dir)
    reg.save(cfg.name, bundle)
    # The serving path must consume the *saved artifact*, not training state.
    loaded = reg.load(cfg.name)

    # bit-exactness gate: engine predictions == lut_forward oracle
    codes = LI.input_codes(cfg, params, jnp.asarray(xte))
    out = LI.lut_forward(cfg, tables, statics, codes)
    ref = np.asarray(jnp.argmax(LI.class_values(cfg, params, out), -1))
    with LUTServeEngine(loaded, use_kernel=False) as eng:
        eng.warmup()
        got = eng.predict(xte)
    exact = bool((got == ref).all())
    emit("serve/registry_bit_exact", 0.0,
         f"exact={exact};acc_q={loaded.meta.get('train_acc_q', 0):.4f}")
    if not exact:
        raise SystemExit("registry round-trip predictions diverge from "
                         "lut_forward oracle")
    # The closed-loop clients slice request payloads host-side.
    return loaded, np.asarray(xte)


def _closed_loop(engine: LUTServeEngine, x: np.ndarray, *, clients: int,
                 requests_per_client: int, request_size: int = 1) -> None:
    def client(cid: int) -> None:
        rng = np.random.default_rng(cid)
        for _ in range(requests_per_client):
            if request_size == 1:
                engine.predict(x[rng.integers(0, len(x))])
            else:
                idx = rng.integers(0, len(x), request_size)
                engine.predict(x[idx])

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def run_replica_sweep(*, reduced: bool = True, epochs: int = 0,
                      arch: str = "neuralut-jsc-2l", registry_dir: str = "",
                      replicas_sweep=(1, 2, 4, 8), clients: int = 64,
                      requests_per_client: int = 0, request_size: int = 64,
                      max_wait_ms: float = 2.0) -> None:
    """Aggregate-throughput scaling across replica executors.

    Fixed high offered load (``clients`` closed-loop clients, each
    submitting ``request_size``-sample requests so every dispatch
    carries real work) against a growing replica pool, so service
    capacity — not the client count — is the bottleneck: aggregate
    throughput should rise monotonically with the replica count
    whenever replicas land on distinct devices (EXPERIMENTS.md
    §Scale-out; the CI multi-device job runs this on a forced 8-device
    host).  Per-replica batch counts come from the engine's per-replica
    metrics and show the router spreading load.
    """
    import jax

    epochs = epochs or (3 if reduced else 20)
    requests_per_client = requests_per_client or (25 if reduced else 100)
    ndev = jax.device_count()
    tmp = None
    if not registry_dir:
        tmp = tempfile.TemporaryDirectory()
        registry_dir = tmp.name
    try:
        bundle, xte = _train_bundle(arch, reduced=reduced, epochs=epochs,
                                    registry_dir=registry_dir)
        for r in replicas_sweep:
            metrics = ServeMetrics()
            with LUTServeEngine(bundle, max_wait_ms=max_wait_ms,
                                use_kernel=False, replicas=r,
                                metrics=metrics) as eng:
                eng.warmup()
                _closed_loop(eng, xte, clients=clients,
                             requests_per_client=requests_per_client,
                             request_size=request_size)
                per_replica = [int(m.report()["batches"])
                               for m in eng.replica_metrics]
            rep = metrics.report()
            emit(f"serve/replicas_r{r}", rep["p50_ms"] * 1e3,
                 f"p50_ms={rep['p50_ms']:.2f};p99_ms={rep['p99_ms']:.2f};"
                 f"throughput_sps={rep['throughput_sps']:.0f};"
                 f"devices={ndev};clients={clients};"
                 f"replica_batches={'/'.join(map(str, per_replica))}")
    finally:
        if tmp is not None:
            tmp.cleanup()


def run(*, reduced: bool = True, epochs: int = 0,
        arch: str = "neuralut-jsc-2l", registry_dir: str = "",
        clients_sweep=(1, 4, 16, 64), requests_per_client: int = 0,
        max_wait_ms: float = 2.0) -> None:
    epochs = epochs or (3 if reduced else 20)
    requests_per_client = requests_per_client or (50 if reduced else 200)
    tmp = None
    if not registry_dir:
        tmp = tempfile.TemporaryDirectory()
        registry_dir = tmp.name
    try:
        bundle, xte = _train_bundle(arch, reduced=reduced, epochs=epochs,
                                    registry_dir=registry_dir)
        for clients in clients_sweep:
            metrics = ServeMetrics()
            with LUTServeEngine(bundle, max_wait_ms=max_wait_ms,
                                use_kernel=False, metrics=metrics) as eng:
                eng.warmup()
                _closed_loop(eng, xte, clients=clients,
                             requests_per_client=requests_per_client)
            r = metrics.report()
            emit(f"serve/closed_loop_c{clients}", r["p50_ms"] * 1e3,
                 f"p50_ms={r['p50_ms']:.2f};p95_ms={r['p95_ms']:.2f};"
                 f"p99_ms={r['p99_ms']:.2f};"
                 f"throughput_sps={r['throughput_sps']:.0f};"
                 f"occupancy={r['batch_occupancy']:.2f};"
                 f"queue_depth={r['mean_queue_depth']:.1f};"
                 f"requests={int(r['requests'])}")
    finally:
        if tmp is not None:
            tmp.cleanup()


def _random_bundle(cfg, seed: int) -> ServeBundle:
    """Serving-ready bundle with random tables/scales: lookup cost does
    not depend on table contents, so the multi-tenant perf section skips
    training and measures pure serving behavior."""
    rng = np.random.default_rng(seed)
    statics, tables = [], []
    w_prev = cfg.in_features
    for i, o in enumerate(cfg.layer_widths):
        f = cfg.layer_fan_in(i)
        statics.append({"conn": rng.integers(0, w_prev, (o, f))})
        tables.append(rng.integers(0, 2 ** cfg.beta,
                                   (o, cfg.table_size(i))).astype(np.uint16))
        w_prev = o
    return ServeBundle(
        cfg=cfg, tables=tables, statics=statics,
        in_log_s=rng.normal(0, 0.3, (cfg.in_features,)).astype(np.float32),
        layer_log_s=[rng.normal(0, 0.3, (o,)).astype(np.float32)
                     for o in cfg.layer_widths]).prepack()


def _mt_closed_loop(engine: MultiTenantEngine, names, x: np.ndarray, *,
                    clients: int, requests_per_client: int,
                    request_size: int) -> None:
    """Closed-loop clients spread round-robin across the tenants."""
    def client(cid: int) -> None:
        tenant = names[cid % len(names)]
        rng = np.random.default_rng(cid)
        for _ in range(requests_per_client):
            idx = rng.integers(0, len(x), request_size)
            engine.predict(tenant, x[idx])

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def run_tenants(*, reduced: bool = True, arch: str = "neuralut-jsc-2l",
                num_tenants: int = 2, clients: int = 8,
                requests_per_client: int = 0, request_size: int = 32,
                max_wait_ms: float = 1.0) -> dict:
    """Multi-tenant consolidation section (BENCH_kernels.json key
    ``serve_tenants``, gated by ``benchmarks/run.py --check``).

    Measures the same offered load two ways in one process:

      * ``single_engine_sps`` — each tenant behind its own
        ``LUTServeEngine``, all engines live at once (the
        pre-consolidation deployment: N processes contending for the
        same host);
      * ``aggregate_sps`` — every tenant behind ONE
        ``MultiTenantEngine`` group, batches packed across tenants
        into a single dispatch stream.

    Both sides serve the identical offered load (same clients, same
    request mix) and are timed wall-clock over the full window.

    ``consolidation_ratio = aggregate_sps / single_engine_sps`` is the
    machine-relative "speedup" metric for the CI gate (robust to runner
    hardware, like the other ratio gates).  ``reduced`` shrinks the
    offered load only, NOT the model geometry: the ratio depends
    strongly on layer widths (tiny layers make the packed one-hot
    einsum overhead dominate), so a smoke run must measure the same
    geometry as the committed baseline to be comparable.  The section
    also records a
    forced-overload shed_rate demo (bounded low-priority queue under
    flood while a high-priority tenant stays clean) and one clean
    hot-swap under live traffic (shadow + cutover latency) —
    EXPERIMENTS.md §Multi-tenant serving.
    """
    requests_per_client = requests_per_client or (20 if reduced else 80)
    cfg = get_config(arch, reduced=False)
    bundles = [_random_bundle(cfg, seed=i) for i in range(num_tenants)]
    names = [f"t{i}" for i in range(num_tenants)]
    x = np.random.default_rng(99).normal(
        0, 1, (4096, cfg.in_features)).astype(np.float32)

    per_tenant_clients = max(1, clients // num_tenants)
    reps = 2  # best-of-2 per side: cancels transient host contention

    # -- baseline: one dedicated engine per tenant, all live at once ------
    def _measure_single() -> float:
        engines = [LUTServeEngine(b, max_wait_ms=max_wait_ms,
                                  use_kernel=False, metrics=ServeMetrics())
                   for b in bundles]
        try:
            for e in engines:
                e.start()
                e.warmup()
            t0 = time.perf_counter()
            threads = [threading.Thread(
                target=_closed_loop, args=(e, x),
                kwargs=dict(clients=per_tenant_clients,
                            requests_per_client=requests_per_client,
                            request_size=request_size))
                for e in engines]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            elapsed = time.perf_counter() - t0
        finally:
            for e in engines:
                e.close()
        samples = sum(e.metrics.report()["samples"] for e in engines)
        return samples / elapsed if elapsed else 0.0

    single_sps = max(_measure_single() for _ in range(reps))
    emit("serve_tenants/single_engine", 0.0,
         f"throughput_sps={single_sps:.0f};tenants={num_tenants};"
         f"reps={reps}")

    # -- consolidated: every tenant behind one packed group ----------------
    def _measure_mt():
        metrics = ServeMetrics()
        eng = MultiTenantEngine(
            [Tenant(n, b) for n, b in zip(names, bundles)],
            max_wait_ms=max_wait_ms, metrics=metrics)
        with eng:
            eng.warmup()
            t0 = time.perf_counter()
            _mt_closed_loop(eng, names, x,
                            clients=per_tenant_clients * num_tenants,
                            requests_per_client=requests_per_client,
                            request_size=request_size)
            elapsed = time.perf_counter() - t0
        rep = metrics.report()
        sps = rep["samples"] / elapsed if elapsed else 0.0
        return sps, rep, eng.num_groups

    aggregate_sps, rep, num_groups = max(
        (_measure_mt() for _ in range(reps)), key=lambda r: r[0])
    ratio = aggregate_sps / single_sps if single_sps else 0.0
    emit("serve_tenants/consolidated", rep["p50_ms"] * 1e3,
         f"p50_ms={rep['p50_ms']:.2f};p99_ms={rep['p99_ms']:.2f};"
         f"throughput_sps={aggregate_sps:.0f};"
         f"consolidation_ratio={ratio:.2f};groups={num_groups}")

    # -- forced overload: bounded low-priority tenant sheds, the
    # high-priority tenant rides through clean -----------------------------
    eng = MultiTenantEngine(
        [Tenant("lo", bundles[0], priority=0, max_queue_depth=4),
         Tenant("hi", bundles[1 % num_tenants], priority=5)],
        max_wait_ms=max_wait_ms)
    with eng:
        eng.warmup()
        stop = threading.Event()

        def flood():
            while not stop.is_set():
                try:
                    eng.submit("lo", x[:request_size])
                except TenantOverloaded:
                    pass

        flooder = threading.Thread(target=flood, daemon=True)
        flooder.start()
        for _ in range(4 * requests_per_client):
            eng.predict("hi", x[:2])
        stop.set()
        flooder.join()
    shed_rate = eng.tenant_metrics("lo").shed_rate
    hi_shed = eng.tenant_metrics("hi").shed
    emit("serve_tenants/overload_shed", 0.0,
         f"lo_shed_rate={shed_rate:.3f};hi_shed={hi_shed};"
         f"hi_p99_ms={eng.tenant_metrics('hi').latency_ms(99):.2f}")

    # -- hot swap under live traffic ---------------------------------------
    eng = MultiTenantEngine([Tenant("live", bundles[0])],
                            max_wait_ms=max_wait_ms)
    candidate = ServeBundle(
        cfg=cfg, tables=[t.copy() for t in bundles[0].tables],
        statics=[{k: v.copy() for k, v in s.items()}
                 for s in bundles[0].statics],
        in_log_s=bundles[0].in_log_s.copy(),
        layer_log_s=[s.copy() for s in bundles[0].layer_log_s])
    with eng:
        eng.warmup()
        stop = threading.Event()

        def traffic():
            while not stop.is_set():
                eng.predict("live", x[:request_size])

        t = threading.Thread(target=traffic, daemon=True)
        t.start()
        swap = eng.swap("live", candidate, shadow_samples=64,
                        timeout_s=60.0)
        stop.set()
        t.join()
    if swap.status != "committed" or swap.mismatches:
        raise SystemExit(f"clean hot-swap failed: {swap}")
    emit("serve_tenants/hot_swap", swap.swap_latency_s * 1e6,
         f"status={swap.status};shadow={swap.shadow_samples};"
         f"swap_s={swap.swap_latency_s:.3f};"
         f"cutover_ms={swap.cutover_latency_s * 1e3:.2f}")

    return {
        "tenants": num_tenants,
        "arch": cfg.name,
        "aggregate_sps": aggregate_sps,
        "single_engine_sps": single_sps,
        "consolidation_ratio": ratio,
        "shed_rate_overload": shed_rate,
        "hi_shed": int(hi_shed),
        "swap_latency_s": swap.swap_latency_s,
        "cutover_latency_s": swap.cutover_latency_s,
        "shadow_samples": int(swap.shadow_samples),
        "fast_mode": reduced,
    }


def _deadline_closed_loop(engine: LUTServeEngine, x: np.ndarray, *,
                          clients: int, requests_per_client: int,
                          request_size: int, timeout_s: float) -> None:
    """Closed loop where every request carries a (generous) deadline —
    the happy-path cost of the deadline bookkeeping, not of expiry."""
    def client(cid: int) -> None:
        rng = np.random.default_rng(cid)
        for _ in range(requests_per_client):
            idx = rng.integers(0, len(x), request_size)
            engine.predict(x[idx], timeout_s=timeout_s)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def run_resilience(*, reduced: bool = True, arch: str = "neuralut-jsc-2l",
                   clients: int = 8, requests_per_client: int = 0,
                   request_size: int = 32,
                   max_wait_ms: float = 1.0) -> dict:
    """Happy-path cost of the fault-tolerance machinery
    (BENCH_kernels.json key ``serve_resilience``, gated by
    ``benchmarks/run.py --check``).

    Measures the identical offered load twice through the same engine
    configuration in one process:

      * ``plain_sps`` — requests without deadlines (the pre-robustness
        client contract; redispatch/health plumbing idle);
      * ``resilient_sps`` — every request carries a generous
        ``timeout_s`` (deadline bookkeeping at each hand-off point) on
        an engine with a revive probe and the default retry budget
        armed; nothing fires on the happy path.

    ``overhead_ratio = resilient_sps / plain_sps`` is the gate metric:
    the checker holds an absolute floor of 0.95 (retry + deadline +
    integrity machinery must cost < 5% cascade throughput when no
    fault occurs).  The section also times the registry integrity
    verification (checksum every array at load) as
    ``verify_ms`` — the artifact-side overhead, off the request path.
    Both sides run three times interleaved and keep their best window,
    so a transient CI hiccup hits both measurements symmetrically.
    """
    requests_per_client = requests_per_client or (25 if reduced else 100)
    cfg = get_config(arch, reduced=False)
    bundle = _random_bundle(cfg, seed=0)

    # Artifact integrity overhead: verified vs unverified load.
    with tempfile.TemporaryDirectory() as td:
        reg = TableRegistry(td)
        reg.save(cfg.name, bundle)
        t0 = time.perf_counter()
        reg.load(cfg.name, verify=False)
        load_plain_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        loaded = reg.load(cfg.name, verify=True)
        load_verified_s = time.perf_counter() - t0
        report = reg.verify(cfg.name)
    verify_ms = max(0.0, (load_verified_s - load_plain_s)) * 1e3
    emit("serve_resilience/integrity_verify", verify_ms * 1e3,
         f"verify_ms={verify_ms:.2f};arrays={report['checked']};"
         f"ok={report['ok']}")

    x = np.random.default_rng(5).normal(
        0, 1, (4096, cfg.in_features)).astype(np.float32)
    total = clients * requests_per_client * request_size

    def _measure(with_deadlines: bool) -> float:
        metrics = ServeMetrics()
        with LUTServeEngine(loaded, max_wait_ms=max_wait_ms,
                            use_kernel=False, metrics=metrics,
                            revive_probe=lambda rid: True) as eng:
            eng.warmup()
            t0 = time.perf_counter()
            if with_deadlines:
                _deadline_closed_loop(
                    eng, x, clients=clients,
                    requests_per_client=requests_per_client,
                    request_size=request_size, timeout_s=120.0)
            else:
                _closed_loop(eng, x, clients=clients,
                             requests_per_client=requests_per_client,
                             request_size=request_size)
            wall = time.perf_counter() - t0
        rep = metrics.report()
        assert rep["deadline_exceeded"] == 0 and rep["shed"] == 0, \
            "happy-path bench must not shed or expire requests"
        return total / wall

    # Interleaved best-of-three: noise hits both sides symmetrically,
    # and the extra rep tightens each side's best-window estimate — the
    # gate holds an absolute 0.95 floor on the ratio, so a single slow
    # window on the resilient side must not read as real overhead.
    plain_sps = resilient_sps = 0.0
    for _ in range(3):
        plain_sps = max(plain_sps, _measure(False))
        resilient_sps = max(resilient_sps, _measure(True))
    ratio = resilient_sps / plain_sps
    emit("serve_resilience/happy_path", 0.0,
         f"plain_sps={plain_sps:.0f};resilient_sps={resilient_sps:.0f};"
         f"overhead_ratio={ratio:.3f}")
    return {
        "arch": cfg.name,
        "plain_sps": plain_sps,
        "resilient_sps": resilient_sps,
        "overhead_ratio": ratio,
        "verify_ms": verify_ms,
        "verify_arrays": int(report["checked"]),
        "fast_mode": reduced,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="tiny model + short sweep (CPU/CI mode)")
    ap.add_argument("--arch", default="neuralut-jsc-2l")
    ap.add_argument("--epochs", type=int, default=0)
    ap.add_argument("--registry", default="",
                    help="persist the bundle here (default: temp dir)")
    ap.add_argument("--clients", type=int, nargs="+",
                    default=[1, 4, 16, 64])
    ap.add_argument("--requests-per-client", type=int, default=0)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--replicas", type=int, nargs="+", default=None,
                    help="sweep replica counts at fixed offered load "
                         "(aggregate-throughput scaling) instead of the "
                         "client sweep; e.g. --replicas 1 2 4 8")
    ap.add_argument("--tenants", type=int, default=0,
                    help="run the multi-tenant consolidation section "
                         "with this many tenants instead of the client "
                         "sweep (see run_tenants)")
    ap.add_argument("--resilience", action="store_true",
                    help="run the fault-tolerance happy-path overhead "
                         "section instead of the client sweep "
                         "(see run_resilience)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.resilience:
        summary = run_resilience(
            reduced=args.reduced, arch=args.arch,
            clients=max(args.clients),
            requests_per_client=args.requests_per_client,
            max_wait_ms=args.max_wait_ms)
        print(f"# {summary}")
    elif args.tenants:
        summary = run_tenants(
            reduced=args.reduced, arch=args.arch,
            num_tenants=args.tenants, clients=max(args.clients),
            requests_per_client=args.requests_per_client,
            max_wait_ms=args.max_wait_ms)
        print(f"# {summary}")
    elif args.replicas:
        run_replica_sweep(
            reduced=args.reduced, epochs=args.epochs, arch=args.arch,
            registry_dir=args.registry,
            replicas_sweep=tuple(args.replicas),
            clients=max(args.clients),
            requests_per_client=args.requests_per_client,
            max_wait_ms=args.max_wait_ms)
    else:
        run(reduced=args.reduced, epochs=args.epochs, arch=args.arch,
            registry_dir=args.registry, clients_sweep=tuple(args.clients),
            requests_per_client=args.requests_per_client,
            max_wait_ms=args.max_wait_ms)


if __name__ == "__main__":
    main()
