"""Closed-loop serving benchmark: offered load vs latency for the LUT engine.

A pool of closed-loop clients (each submits a single-sample request, waits
for the prediction, submits the next) drives ``repro.serve.LUTServeEngine``;
sweeping the client count sweeps offered load.  For each concurrency level
we report the engine's own metrics — p50/p95/p99 end-to-end latency,
achieved throughput, mean queue depth and batch occupancy — which together
form the repo's serving performance trajectory (EXPERIMENTS.md §Perf,
serving section).

The bundle is trained once, saved through the registry, and *loaded back*
before serving, so the bench also exercises the deploy path end to end and
verifies bit-exactness against the ``lut_infer.lut_forward`` oracle.

    PYTHONPATH=src python benchmarks/serve_bench.py --reduced

Emits CSV lines ``name,us_per_call,derived`` (benchmarks/common.py); the
us_per_call column carries the p50 request latency.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import tempfile
import threading

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import get_config
from repro.core import lut_infer as LI
from repro.core import model as M
from repro.core import truth_table as TT
from repro.core.train import train_neuralut
from repro.data import device_dataset, jsc_synthetic
from repro.serve import (LUTServeEngine, ServeMetrics, TableRegistry,
                         bundle_from_training)


def _train_bundle(arch: str, *, reduced: bool, epochs: int, registry_dir: str):
    cfg = get_config(arch, reduced=reduced)
    xtr, ytr = device_dataset(jsc_synthetic, 8000 if reduced else 20000,
                              seed=0)
    xte, yte = device_dataset(jsc_synthetic, 2000, seed=1)
    params, state, hist = train_neuralut(
        cfg, xtr, ytr, xte, yte, epochs=epochs, batch=256, lr=2e-3)
    statics = M.model_static(cfg)
    tables, packed = TT.convert_packed(cfg, params, state, statics)
    bundle = bundle_from_training(
        cfg, params, tables, statics, packed_tables=packed,
        meta={"train_acc_q": float(hist["test_acc_q"][-1])})
    reg = TableRegistry(registry_dir)
    reg.save(cfg.name, bundle)
    # The serving path must consume the *saved artifact*, not training state.
    loaded = reg.load(cfg.name)

    # bit-exactness gate: engine predictions == lut_forward oracle
    codes = LI.input_codes(cfg, params, jnp.asarray(xte))
    out = LI.lut_forward(cfg, tables, statics, codes)
    ref = np.asarray(jnp.argmax(LI.class_values(cfg, params, out), -1))
    with LUTServeEngine(loaded, use_kernel=False) as eng:
        eng.warmup()
        got = eng.predict(xte)
    exact = bool((got == ref).all())
    emit("serve/registry_bit_exact", 0.0,
         f"exact={exact};acc_q={loaded.meta.get('train_acc_q', 0):.4f}")
    if not exact:
        raise SystemExit("registry round-trip predictions diverge from "
                         "lut_forward oracle")
    # The closed-loop clients slice request payloads host-side.
    return loaded, np.asarray(xte)


def _closed_loop(engine: LUTServeEngine, x: np.ndarray, *, clients: int,
                 requests_per_client: int, request_size: int = 1) -> None:
    def client(cid: int) -> None:
        rng = np.random.default_rng(cid)
        for _ in range(requests_per_client):
            if request_size == 1:
                engine.predict(x[rng.integers(0, len(x))])
            else:
                idx = rng.integers(0, len(x), request_size)
                engine.predict(x[idx])

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def run_replica_sweep(*, reduced: bool = True, epochs: int = 0,
                      arch: str = "neuralut-jsc-2l", registry_dir: str = "",
                      replicas_sweep=(1, 2, 4, 8), clients: int = 64,
                      requests_per_client: int = 0, request_size: int = 64,
                      max_wait_ms: float = 2.0) -> None:
    """Aggregate-throughput scaling across replica executors.

    Fixed high offered load (``clients`` closed-loop clients, each
    submitting ``request_size``-sample requests so every dispatch
    carries real work) against a growing replica pool, so service
    capacity — not the client count — is the bottleneck: aggregate
    throughput should rise monotonically with the replica count
    whenever replicas land on distinct devices (EXPERIMENTS.md
    §Scale-out; the CI multi-device job runs this on a forced 8-device
    host).  Per-replica batch counts come from the engine's per-replica
    metrics and show the router spreading load.
    """
    import jax

    epochs = epochs or (3 if reduced else 20)
    requests_per_client = requests_per_client or (25 if reduced else 100)
    ndev = jax.device_count()
    tmp = None
    if not registry_dir:
        tmp = tempfile.TemporaryDirectory()
        registry_dir = tmp.name
    try:
        bundle, xte = _train_bundle(arch, reduced=reduced, epochs=epochs,
                                    registry_dir=registry_dir)
        for r in replicas_sweep:
            metrics = ServeMetrics()
            with LUTServeEngine(bundle, max_wait_ms=max_wait_ms,
                                use_kernel=False, replicas=r,
                                metrics=metrics) as eng:
                eng.warmup()
                _closed_loop(eng, xte, clients=clients,
                             requests_per_client=requests_per_client,
                             request_size=request_size)
                per_replica = [int(m.report()["batches"])
                               for m in eng.replica_metrics]
            rep = metrics.report()
            emit(f"serve/replicas_r{r}", rep["p50_ms"] * 1e3,
                 f"p50_ms={rep['p50_ms']:.2f};p99_ms={rep['p99_ms']:.2f};"
                 f"throughput_sps={rep['throughput_sps']:.0f};"
                 f"devices={ndev};clients={clients};"
                 f"replica_batches={'/'.join(map(str, per_replica))}")
    finally:
        if tmp is not None:
            tmp.cleanup()


def run(*, reduced: bool = True, epochs: int = 0,
        arch: str = "neuralut-jsc-2l", registry_dir: str = "",
        clients_sweep=(1, 4, 16, 64), requests_per_client: int = 0,
        max_wait_ms: float = 2.0) -> None:
    epochs = epochs or (3 if reduced else 20)
    requests_per_client = requests_per_client or (50 if reduced else 200)
    tmp = None
    if not registry_dir:
        tmp = tempfile.TemporaryDirectory()
        registry_dir = tmp.name
    try:
        bundle, xte = _train_bundle(arch, reduced=reduced, epochs=epochs,
                                    registry_dir=registry_dir)
        for clients in clients_sweep:
            metrics = ServeMetrics()
            with LUTServeEngine(bundle, max_wait_ms=max_wait_ms,
                                use_kernel=False, metrics=metrics) as eng:
                eng.warmup()
                _closed_loop(eng, xte, clients=clients,
                             requests_per_client=requests_per_client)
            r = metrics.report()
            emit(f"serve/closed_loop_c{clients}", r["p50_ms"] * 1e3,
                 f"p50_ms={r['p50_ms']:.2f};p95_ms={r['p95_ms']:.2f};"
                 f"p99_ms={r['p99_ms']:.2f};"
                 f"throughput_sps={r['throughput_sps']:.0f};"
                 f"occupancy={r['batch_occupancy']:.2f};"
                 f"queue_depth={r['mean_queue_depth']:.1f};"
                 f"requests={int(r['requests'])}")
    finally:
        if tmp is not None:
            tmp.cleanup()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="tiny model + short sweep (CPU/CI mode)")
    ap.add_argument("--arch", default="neuralut-jsc-2l")
    ap.add_argument("--epochs", type=int, default=0)
    ap.add_argument("--registry", default="",
                    help="persist the bundle here (default: temp dir)")
    ap.add_argument("--clients", type=int, nargs="+",
                    default=[1, 4, 16, 64])
    ap.add_argument("--requests-per-client", type=int, default=0)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--replicas", type=int, nargs="+", default=None,
                    help="sweep replica counts at fixed offered load "
                         "(aggregate-throughput scaling) instead of the "
                         "client sweep; e.g. --replicas 1 2 4 8")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.replicas:
        run_replica_sweep(
            reduced=args.reduced, epochs=args.epochs, arch=args.arch,
            registry_dir=args.registry,
            replicas_sweep=tuple(args.replicas),
            clients=max(args.clients),
            requests_per_client=args.requests_per_client,
            max_wait_ms=args.max_wait_ms)
    else:
        run(reduced=args.reduced, epochs=args.epochs, arch=args.arch,
            registry_dir=args.registry, clients_sweep=tuple(args.clients),
            requests_per_client=args.requests_per_client,
            max_wait_ms=args.max_wait_ms)


if __name__ == "__main__":
    main()
