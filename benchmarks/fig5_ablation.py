"""Fig. 5: sub-network depth ablation with/without skip-connections.

Fixed circuit-level architecture; the hidden function varies:
  baseline (LogicNets, L=1) -> NeuraLUT L in {2, 3, 4} x {skip, no-skip}.
The paper's claims: every NeuraLUT point beats the baseline at equal L-LUT
count; with skips accuracy improves with depth (L=3 -> L=4 up), without
skips it degrades.

CPU-sized stand-in: reduced circuit (64 inputs) on synthetic MNIST; the
*orderings* are the reproduction target (see DESIGN.md §Datasets).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.nl_config import NeuraLUTConfig
from repro.core.train import train_neuralut
from repro.data import mnist_synthetic

SEEDS = (0, 1, 2)


def _cfg(L: int, S: int) -> NeuraLUTConfig:
    return NeuraLUTConfig(
        name=f"fig5-L{L}-S{S}", in_features=196, layer_widths=(64, 32, 10),
        num_classes=10, beta=2, fan_in=6,
        kind="subnet" if L > 1 else "linear",
        depth=L, width=16, skip=S)


def _pool(x: np.ndarray) -> np.ndarray:
    """28x28 -> 14x14 average pool => 196 standardized features."""
    img = x.reshape(-1, 28, 28)
    out = img.reshape(-1, 14, 2, 14, 2).mean((2, 4)).reshape(-1, 196)
    return (out - out.mean(0)) / (out.std(0) + 1e-6)


def run(epochs: int = 12, n_train: int = 6000) -> None:
    xtr, ytr = mnist_synthetic(n_train, seed=0)
    xte, yte = mnist_synthetic(1500, seed=1)
    xtr, xte = _pool(xtr), _pool(xte)

    results = {}
    for L, S in ((1, 0), (2, 0), (2, 2), (4, 0), (4, 2)):
        accs = []
        t0 = time.time()
        for seed in SEEDS:
            _, _, hist = train_neuralut(_cfg(L, S), xtr, ytr, xte, yte,
                                        epochs=epochs, batch=256, lr=3e-3,
                                        seed=seed)
            accs.append(hist["test_acc_q"][-1])
        results[(L, S)] = float(np.mean(accs))
        emit(f"fig5/L{L}_S{S}", (time.time() - t0) / len(SEEDS) * 1e6,
             f"acc_mean={np.mean(accs):.4f};acc_std={np.std(accs):.4f}")

    base = results[(1, 0)]
    emit("fig5/claim_all_neuralut_beat_baseline", 0.0,
         f"{all(v > base for k, v in results.items() if k != (1, 0))}")
    emit("fig5/claim_skips_help_depth", 0.0,
         f"L4_skip={results[(4, 2)]:.4f}>=L4_noskip={results[(4, 0)]:.4f}:"
         f"{results[(4, 2)] >= results[(4, 0)] - 0.005}")


if __name__ == "__main__":
    run()
