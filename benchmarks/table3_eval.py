"""Table III: the paper's headline evaluation.

For each published model (JSC-2L, JSC-5L, HDR-5L): train on the synthetic
stand-in dataset, convert to truth tables, assert the LUT path is bit-exact,
and report accuracy + modeled LUT/Fmax/latency/area-delay next to the
paper's reported numbers.  Absolute accuracy differs (synthetic data);
hardware-side numbers depend only on topology and are compared directly.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import get_config
from repro.core import cost_model as CM
from repro.core import lut_infer as LI
from repro.core import model as M
from repro.core import truth_table as TT
from repro.core.train import train_neuralut
from repro.data import (device_dataset, jsc_synthetic,
                        mnist_synthetic)


def _eval_model(arch: str, xtr, ytr, xte, yte, epochs: int):
    cfg = get_config(arch)
    t0 = time.time()
    params, state, hist = train_neuralut(cfg, xtr, ytr, xte, yte,
                                         epochs=epochs, batch=256, lr=2e-3)
    train_s = time.time() - t0
    statics = M.model_static(cfg)
    t1 = time.time()
    tables = TT.convert(cfg, params, state, statics)
    convert_s = time.time() - t1

    # bit-exactness on the test set
    _, values, _ = M.model_apply(cfg, params, state, statics,
                                 jnp.asarray(xte), train=False)
    codes = LI.input_codes(cfg, params, jnp.asarray(xte))
    out = LI.lut_forward(cfg, tables, statics, codes)
    exact = float((np.asarray(values)
                   == np.asarray(LI.class_values(cfg, params, out))).mean())

    est = CM.estimate(cfg)
    paper = CM.PAPER_TABLE3.get(arch, {})
    emit(f"table3/{arch}", train_s * 1e6,
         f"acc_q={hist['test_acc_q'][-1]:.4f};bit_exact={exact:.3f};"
         f"luts={est.luts:.0f}(paper={paper.get('lut')});"
         f"fmax={est.fmax_mhz:.0f}(paper={paper.get('fmax')});"
         f"latency_ns={est.latency_ns:.1f}(paper={paper.get('latency')});"
         f"adp={est.area_delay:.2e}(paper={paper.get('adp'):.2e});"
         f"convert_s={convert_s:.1f}")
    return est


def run(fast: bool = False) -> None:
    ep_jsc = 8 if fast else 25
    ep_mnist = 4 if fast else 12
    xtr, ytr = device_dataset(jsc_synthetic, 20000, seed=0)
    xte, yte = device_dataset(jsc_synthetic, 4000, seed=1)
    e2 = _eval_model("neuralut-jsc-2l", xtr, ytr, xte, yte, ep_jsc)
    e5 = _eval_model("neuralut-jsc-5l", xtr, ytr, xte, yte, ep_jsc)

    xtr, ytr = device_dataset(mnist_synthetic, 8000, seed=0)
    xte, yte = device_dataset(mnist_synthetic, 2000, seed=1)
    eh = _eval_model("neuralut-hdr-5l", xtr, ytr, xte, yte, ep_mnist)

    # headline ratios vs published baselines (modeled / paper-reported)
    p = CM.PAPER_TABLE3
    emit("table3/adp_ratio_jsc2l_vs_logicnets", 0.0,
         f"model={p['logicnets-jsc-m']['adp']/e2.area_delay:.1f}x"
         f"(paper=35.2x)")
    emit("table3/adp_ratio_jsc2l_vs_polylut", 0.0,
         f"model={p['polylut-jsc-lite']['adp']/e2.area_delay:.1f}x"
         f"(paper=4.4x)")
    emit("table3/latency_ratio_hdr_vs_polylut", 0.0,
         f"model={p['polylut-hdr']['latency']/eh.latency_ns:.2f}x"
         f"(paper=1.33x)")


if __name__ == "__main__":
    run()
