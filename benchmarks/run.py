# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + kernel/LM benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Emits CSV lines ``name,us_per_call,derived`` (see benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer epochs/seeds (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (fig3_boundaries, fig5_ablation, fig6_7_pareto,
                            kernel_bench, lm_step_bench, serve_bench,
                            table1_params, table3_eval)

    suites = {
        "table1": lambda: table1_params.run(),
        "fig3": lambda: fig3_boundaries.run(epochs=8 if args.fast else 20),
        "fig5": lambda: fig5_ablation.run(
            epochs=5 if args.fast else 12,
            n_train=3000 if args.fast else 6000),
        "fig6_7": lambda: fig6_7_pareto.run(
            epochs=4 if args.fast else 10,
            n_train=3000 if args.fast else 6000),
        "table3": lambda: table3_eval.run(fast=args.fast),
        "kernel": lambda: kernel_bench.run(fast=args.fast),
        "lm_step": lambda: lm_step_bench.run(),
        "serve": lambda: serve_bench.run(reduced=args.fast),
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            result = fn()
            if name == "kernel" and result:
                from benchmarks.common import write_kernel_summary
                write_kernel_summary(result)
            print(f"# suite {name} done in {time.time()-t0:.0f}s",
                  flush=True)
        except Exception:
            failed.append(name)
            print(f"# suite {name} FAILED:", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(f"failed suites: {failed}")


if __name__ == "__main__":
    main()
