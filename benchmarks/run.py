# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + kernel/LM benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME] [--check]

Emits CSV lines ``name,us_per_call,derived`` (see benchmarks/common.py).

``--check`` is the CI perf-regression gate: after the kernel suite runs
(use ``--fast --only kernel`` in CI), the fresh fused-cascade throughput
is compared against the *committed* BENCH_kernels.json baseline — read
before the run overwrites it — and the process exits non-zero if any
common batch size regressed by more than ``--check-threshold`` (default
25%).  A selected suite that raises also exits non-zero, so a red bench
can never slip through as a green step with a partial JSON.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Dict, List

BASELINE = Path(__file__).resolve().parent.parent / "BENCH_kernels.json"


def check_regression(baseline: Dict, fresh: Dict, threshold: float,
                     metric: str = "throughput") -> List[str]:
    """Compare the fresh cascade summary against the committed baseline.

    Gates the fused cascade (the serving fast path) per batch size
    present in both sweeps — smoke runs sweep a subset of the full
    baseline's batches, so only the intersection is comparable.
    ``metric="throughput"`` gates absolute ``fused_lookups_per_s``
    (meaningful when baseline and CI run on comparable machines);
    ``metric="speedup"`` gates the fused-vs-per-layer ratio, which is
    machine-relative and robust to runner hardware differences.
    Returns human-readable problem strings (empty = pass).
    """
    key = {"throughput": "fused_lookups_per_s",
           "speedup": "speedup"}[metric]
    problems: List[str] = []
    base_rows = {r["batch"]: r
                 for r in baseline.get("cascade", {}).get("sweep", [])}
    fresh_rows = {r["batch"]: r for r in fresh.get("sweep", [])}
    common = sorted(set(base_rows) & set(fresh_rows))
    if not common:
        return [f"no common batch sizes between baseline "
                f"{sorted(base_rows)} and fresh run {sorted(fresh_rows)}"]
    for b in common:
        base = float(base_rows[b][key])
        new = float(fresh_rows[b][key])
        floor = (1.0 - threshold) * base
        if new < floor:
            problems.append(
                f"batch {b}: fused cascade {metric} {new:.3e} is "
                f"{(1 - new / base) * 100:.1f}% below baseline "
                f"{base:.3e} (allowed {threshold * 100:.0f}%)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer epochs/seeds (CI mode)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--check", action="store_true",
                    help="gate the fresh kernel numbers against the "
                         "committed BENCH_kernels.json baseline")
    ap.add_argument("--baseline", default=str(BASELINE),
                    help="baseline JSON for --check")
    ap.add_argument("--check-threshold", type=float, default=0.25,
                    help="max allowed fractional regression")
    ap.add_argument("--check-metric", default="throughput",
                    choices=["throughput", "speedup"],
                    help="gate absolute fused throughput, or the "
                         "fused-vs-per-layer speedup ratio (neither is "
                         "fully machine-independent: refresh the "
                         "baseline when CI hardware changes)")
    args = ap.parse_args()

    from benchmarks import (fig3_boundaries, fig5_ablation, fig6_7_pareto,
                            kernel_bench, lm_step_bench, serve_bench,
                            table1_params, table3_eval)

    suites = {
        "table1": lambda: table1_params.run(),
        "fig3": lambda: fig3_boundaries.run(epochs=8 if args.fast else 20),
        "fig5": lambda: fig5_ablation.run(
            epochs=5 if args.fast else 12,
            n_train=3000 if args.fast else 6000),
        "fig6_7": lambda: fig6_7_pareto.run(
            epochs=4 if args.fast else 10,
            n_train=3000 if args.fast else 6000),
        "table3": lambda: table3_eval.run(fast=args.fast),
        "kernel": lambda: kernel_bench.run(fast=args.fast),
        "lm_step": lambda: lm_step_bench.run(),
        "serve": lambda: serve_bench.run(reduced=args.fast),
    }
    if args.only is not None and args.only not in suites:
        sys.exit(f"unknown suite {args.only!r}; choose from "
                 f"{sorted(suites)}")
    if args.check and args.only not in (None, "kernel"):
        sys.exit("--check gates the kernel suite; drop --only or use "
                 "--only kernel")

    # Read the committed baseline BEFORE the run overwrites it.
    baseline = None
    if args.check:
        base_path = Path(args.baseline)
        if not base_path.is_file():
            sys.exit(f"--check: baseline {base_path} does not exist")
        baseline = json.loads(base_path.read_text())

    print("name,us_per_call,derived")
    failed = []
    cascade_summary = None
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            result = fn()
            if name == "kernel" and result:
                cascade_summary = result
                from benchmarks.common import write_kernel_summary
                write_kernel_summary(result)
            print(f"# suite {name} done in {time.time()-t0:.0f}s",
                  flush=True)
        except Exception:
            failed.append(name)
            print(f"# suite {name} FAILED:", flush=True)
            traceback.print_exc()
    if failed:
        print(f"# failed suites: {failed}", file=sys.stderr, flush=True)
        sys.exit(1)
    if args.check:
        if cascade_summary is None:
            sys.exit("--check: kernel suite did not run or produced no "
                     "cascade summary")
        problems = check_regression(baseline, cascade_summary,
                                    args.check_threshold,
                                    metric=args.check_metric)
        if problems:
            for p in problems:
                print(f"# PERF REGRESSION: {p}", file=sys.stderr,
                      flush=True)
            sys.exit(1)
        print("# perf check passed vs baseline", flush=True)


if __name__ == "__main__":
    main()
