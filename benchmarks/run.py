# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + kernel/LM/train/
convert benches.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only A,B] [--check]

Emits CSV lines ``name,us_per_call,derived`` (see benchmarks/common.py).
``--only`` takes one suite or a comma-separated list.

``--check`` is the CI perf-regression gate: after the perf suites run
(use ``--fast --only kernel,train,convert`` in CI), the fresh numbers
are compared against the *committed* BENCH_kernels.json baseline — read
before the run overwrites it — and the process exits non-zero if any
gated metric regressed by more than ``--check-threshold`` (default 25%).
Gated sections (each compared only when present in both baseline and
fresh run):

  * "cascade"      — fused LUT-cascade serving throughput per batch;
  * "cascade_dag"  — LUT-graph single-launch DAG walk vs per-node
                     dispatch on the PolyLUT-Add adder-tree (speedup
                     metric gates the machine-relative ratio);
  * "cascade_cpu"  — cache-blocked gather cascade (the
                     ``fused_cpu_blocked`` route) vs a vendored copy of
                     the packed shift-matmul path it replaced as the
                     CPU serving default (speedup metric gates the
                     machine-relative ratio per batch);
  * "train"        — scanned-trainer steps/s on the JSC-5L model;
  * "train_kernel" — fused fwd+bwd kernel-route step vs the jnp route
                     (speedup metric gates the machine-relative ratio);
  * "convert"      — fused conversion entries/s per paper geometry;
  * "serve_tenants"— multi-tenant consolidation: aggregate packed
                     throughput vs one-engine-per-tenant (speedup mode
                     gates the consolidation ratio);
  * "sweep"        — mesh Pareto sweep engine vs the vendored
                     sequential per-geometry loop (speedup mode gates
                     the engine-vs-loop total wall-clock ratio, which
                     is machine-relative: both sides run in the same
                     process on the same devices).

A selected suite that raises also exits non-zero, so a red bench can
never slip through as a green step with a partial JSON.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path
from typing import Dict, List

from benchmarks.common import GATED_SUITES as GATED

BASELINE = Path(__file__).resolve().parent.parent / "BENCH_kernels.json"


def _gate(problems: List[str], section: str, key: str, base: float,
          new: float, threshold: float) -> None:
    floor = (1.0 - threshold) * base
    if new < floor:
        problems.append(
            f"{section} {key}: {new:.3e} is {(1 - new / base) * 100:.1f}% "
            f"below baseline {base:.3e} (allowed {threshold * 100:.0f}%)")


def _check_cascade(baseline: Dict, fresh: Dict, threshold: float,
                   metric: str, section: str = "cascade") -> List[str]:
    """Per-batch-size gate on a fused cascade sweep (chain "cascade" or
    LUT-graph "cascade_dag" — same sweep schema).  Smoke runs sweep a
    subset of the full baseline's batches, so only the intersection is
    comparable.  ``metric="throughput"`` gates absolute
    ``fused_lookups_per_s`` (meaningful when baseline and CI run on
    comparable machines); ``metric="speedup"`` gates the fused-vs-
    per-layer (per-node for the DAG section) ratio, which is machine-
    relative and robust to runner hardware differences."""
    key = {"throughput": "fused_lookups_per_s",
           "speedup": "speedup"}[metric]
    problems: List[str] = []
    base_rows = {r["batch"]: r for r in baseline.get("sweep", [])}
    fresh_rows = {r["batch"]: r for r in fresh.get("sweep", [])}
    common = sorted(set(base_rows) & set(fresh_rows))
    if not common:
        return [f"{section}: no common batch sizes between baseline "
                f"{sorted(base_rows)} and fresh run {sorted(fresh_rows)}"]
    for b in common:
        _gate(problems, section, f"batch {b} {metric}",
              float(base_rows[b][key]), float(fresh_rows[b][key]),
              threshold)
    return problems


def _check_cascade_dag(baseline: Dict, fresh: Dict, threshold: float,
                       metric: str) -> List[str]:
    """Gate the single-launch DAG walk vs the per-node dispatch path on
    the PolyLUT-Add adder-tree geometry (same schema as "cascade")."""
    return _check_cascade(baseline, fresh, threshold, metric,
                          section="cascade_dag")


def _check_cascade_cpu(baseline: Dict, fresh: Dict, threshold: float,
                       metric: str) -> List[str]:
    """Gate the cache-blocked CPU route vs its vendored packed-ref
    baseline (same sweep schema as "cascade"; ``speedup`` mode gates
    the blocked-vs-packed ratio, which is machine-relative)."""
    return _check_cascade(baseline, fresh, threshold, metric,
                          section="cascade_cpu")


def _check_train(baseline: Dict, fresh: Dict, threshold: float,
                 metric: str) -> List[str]:
    """Gate the scanned trainer: absolute steps/s, or the scanned-vs-
    host-sync ratio in ``speedup`` mode."""
    key = {"throughput": "scanned_steps_per_s", "speedup": "speedup"}[metric]
    problems: List[str] = []
    if key not in baseline or key not in fresh:
        return [f"train: metric {key!r} missing from "
                f"{'baseline' if key not in baseline else 'fresh run'}"]
    _gate(problems, "train", key, float(baseline[key]), float(fresh[key]),
          threshold)
    return problems


def _check_train_kernel(baseline: Dict, fresh: Dict, threshold: float,
                        metric: str) -> List[str]:
    """Gate the fused fwd+bwd kernel training step: absolute steps/s,
    or the kernel-vs-jnp step ratio in ``speedup`` mode (the ratio is
    machine-relative, so it survives runner hardware differences — and
    it gates the interpret-mode overhead staying bounded on CPU CI)."""
    key = {"throughput": "kernel_steps_per_s", "speedup": "speedup"}[metric]
    problems: List[str] = []
    if key not in baseline or key not in fresh:
        return [f"train_kernel: metric {key!r} missing from "
                f"{'baseline' if key not in baseline else 'fresh run'}"]
    _gate(problems, "train_kernel", key, float(baseline[key]),
          float(fresh[key]), threshold)
    return problems


def _check_convert(baseline: Dict, fresh: Dict, threshold: float,
                   metric: str) -> List[str]:
    """Per-geometry gate on fused conversion throughput (or the fused-
    vs-legacy speedup in ``speedup`` mode); smoke runs convert a subset
    of the geometries, so only the intersection is comparable.  Rows
    flagged ``gate: false`` (sub-millisecond tiny geometries, pure
    dispatch noise) are recorded but not compared."""
    key = {"throughput": "entries_per_s", "speedup": "speedup"}[metric]
    problems: List[str] = []
    base_rows = baseline.get("geometries", {})
    fresh_rows = fresh.get("geometries", {})
    common = sorted(set(base_rows) & set(fresh_rows))
    if not common:
        return [f"convert: no common geometries between baseline "
                f"{sorted(base_rows)} and fresh run {sorted(fresh_rows)}"]
    gated = [g for g in common
             if base_rows[g].get("gate", True)
             and fresh_rows[g].get("gate", True)]
    if not gated:
        return [f"convert: no gate-eligible geometries among {common}"]
    for g in gated:
        _gate(problems, "convert", f"{g} {metric}",
              float(base_rows[g][key]), float(fresh_rows[g][key]),
              threshold)
    return problems


def _check_serve_tenants(baseline: Dict, fresh: Dict, threshold: float,
                         metric: str) -> List[str]:
    """Gate the multi-tenant serving section: absolute aggregate
    samples/s through the consolidated engine, or (``speedup`` mode) the
    consolidation ratio — aggregate multi-tenant throughput over the
    one-engine-per-tenant baseline measured in the same process, which
    is machine-relative and survives runner hardware differences."""
    key = {"throughput": "aggregate_sps",
           "speedup": "consolidation_ratio"}[metric]
    problems: List[str] = []
    if key not in baseline or key not in fresh:
        return [f"serve_tenants: metric {key!r} missing from "
                f"{'baseline' if key not in baseline else 'fresh run'}"]
    _gate(problems, "serve_tenants", key, float(baseline[key]),
          float(fresh[key]), threshold)
    return problems


def _check_serve_resilience(baseline: Dict, fresh: Dict, threshold: float,
                            metric: str) -> List[str]:
    """Gate the fault-tolerance happy path: absolute throughput with
    every request carrying a deadline, or (``speedup`` mode) the
    resilient-vs-plain ratio.  Independently of the baseline
    comparison, the fresh ``overhead_ratio`` must clear an absolute
    0.95 floor — the retry/deadline/integrity machinery may not cost
    more than 5% of cascade serving throughput when no fault fires."""
    key = {"throughput": "resilient_sps",
           "speedup": "overhead_ratio"}[metric]
    problems: List[str] = []
    if "overhead_ratio" in fresh and float(fresh["overhead_ratio"]) < 0.95:
        problems.append(
            f"serve_resilience: overhead_ratio "
            f"{float(fresh['overhead_ratio']):.3f} below the absolute "
            f"0.95 floor (fault-tolerance machinery costs >5% on the "
            f"happy path)")
    if key not in baseline or key not in fresh:
        return problems + [
            f"serve_resilience: metric {key!r} missing from "
            f"{'baseline' if key not in baseline else 'fresh run'}"]
    _gate(problems, "serve_resilience", key, float(baseline[key]),
          float(fresh[key]), threshold)
    return problems


def _check_sweep(baseline: Dict, fresh: Dict, threshold: float,
                 metric: str) -> List[str]:
    """Gate the Pareto sweep engine: trained (point, seed) units per
    engine-second, or (``speedup`` mode) the engine-vs-sequential-loop
    total wall-clock ratio — both paths measured in the same process, so
    the ratio survives runner hardware differences.  The ratio's floor
    is what holds the engine's one-compile-per-group amortization (and
    its mesh scaling, when the runner has devices) from regressing back
    toward one-compile-per-point."""
    key = {"throughput": "units_per_s", "speedup": "speedup"}[metric]
    problems: List[str] = []
    if key not in baseline or key not in fresh:
        return [f"sweep: metric {key!r} missing from "
                f"{'baseline' if key not in baseline else 'fresh run'}"]
    _gate(problems, "sweep", key, float(baseline[key]), float(fresh[key]),
          threshold)
    return problems


def check_regression(baseline: Dict, fresh: Dict, threshold: float,
                     metric: str = "throughput") -> List[str]:
    """Compare a fresh run's summaries against the committed baseline.

    ``baseline`` is the committed BENCH_kernels.json payload; ``fresh``
    maps JSON section keys ("cascade" / "train" / "convert") to the
    summaries produced this run.  Sections absent on either side are
    skipped; if NO section is comparable the check fails (a gate that
    gates nothing is a misconfiguration, not a pass).  Neither metric
    mode is fully machine-independent: refresh the baseline when CI
    hardware changes.  Returns human-readable problem strings (empty =
    pass).
    """
    checkers = {"cascade": _check_cascade,
                "cascade_dag": _check_cascade_dag,
                "cascade_cpu": _check_cascade_cpu, "train": _check_train,
                "train_kernel": _check_train_kernel,
                "convert": _check_convert,
                "serve_tenants": _check_serve_tenants,
                "serve_resilience": _check_serve_resilience,
                "sweep": _check_sweep}
    problems: List[str] = []
    compared = 0
    for section, checker in checkers.items():
        if section in fresh and section in baseline:
            compared += 1
            problems += checker(baseline[section], fresh[section],
                                threshold, metric)
    if not compared:
        problems.append(
            f"nothing to compare: baseline has "
            f"{sorted(set(baseline) & set(checkers))}, fresh run produced "
            f"{sorted(set(fresh) & set(checkers))}")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer epochs/seeds (CI mode)")
    ap.add_argument("--only", default=None,
                    help="run only these suites (comma-separated)")
    ap.add_argument("--check", action="store_true",
                    help="gate the fresh perf numbers against the "
                         "committed BENCH_kernels.json baseline")
    ap.add_argument("--baseline", default=str(BASELINE),
                    help="baseline JSON for --check")
    ap.add_argument("--check-threshold", type=float, default=0.25,
                    help="max allowed fractional regression")
    ap.add_argument("--check-metric", default="throughput",
                    choices=["throughput", "speedup"],
                    help="gate absolute throughputs, or the machine-"
                         "relative speedup ratios")
    ap.add_argument("--backend", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="force the cascade route the kernel suites use "
                         "for their bit-exactness record (kernel routes "
                         "run in interpret emulation where the "
                         "accelerator is absent); default keeps the "
                         "Mosaic-TPU kernel body")
    args = ap.parse_args()

    from benchmarks import (convert_bench, fig3_boundaries, fig5_ablation,
                            fig6_7_pareto, kernel_bench, lm_step_bench,
                            serve_bench, table1_params, table3_eval,
                            train_bench)

    suites = {
        "table1": lambda: table1_params.run(),
        "fig3": lambda: fig3_boundaries.run(epochs=8 if args.fast else 20),
        "fig5": lambda: fig5_ablation.run(
            epochs=5 if args.fast else 12,
            n_train=3000 if args.fast else 6000),
        "fig6_7": lambda: fig6_7_pareto.run(
            epochs=4 if args.fast else 10,
            n_train=3000 if args.fast else 6000,
            seeds=2 if args.fast else 3),
        "table3": lambda: table3_eval.run(fast=args.fast),
        "kernel": lambda: kernel_bench.run(fast=args.fast,
                                           backend=args.backend),
        "kernel_dag": lambda: kernel_bench.run_dag(fast=args.fast,
                                                   backend=args.backend),
        "kernel_cpu": lambda: kernel_bench.run_cpu(fast=args.fast),
        "train": lambda: train_bench.run(fast=args.fast),
        "train_kernel": lambda: train_bench.run_kernel(fast=args.fast),
        "convert": lambda: convert_bench.run(fast=args.fast),
        "lm_step": lambda: lm_step_bench.run(),
        "serve": lambda: serve_bench.run(reduced=args.fast),
        "serve_tenants": lambda: serve_bench.run_tenants(reduced=args.fast),
        "serve_resilience": lambda: serve_bench.run_resilience(
            reduced=args.fast),
        "sweep": lambda: fig6_7_pareto.run_sweep_bench(fast=args.fast),
    }
    selected = list(suites) if args.only is None else [
        s.strip() for s in args.only.split(",") if s.strip()]
    unknown = [s for s in selected if s not in suites]
    if unknown:
        sys.exit(f"unknown suite(s) {unknown}; choose from "
                 f"{sorted(suites)}")
    if args.check and not any(s in GATED for s in selected):
        sys.exit("--check gates the kernel/train/convert suites; select "
                 "at least one of them (or drop --only)")

    # Read the committed baseline BEFORE the run overwrites it.
    baseline = None
    if args.check:
        base_path = Path(args.baseline)
        if not base_path.is_file():
            sys.exit(f"--check: baseline {base_path} does not exist")
        baseline = json.loads(base_path.read_text())

    print("name,us_per_call,derived")
    failed = []
    summaries: Dict[str, Dict] = {}
    for name in selected:
        t0 = time.time()
        try:
            result = suites[name]()
            if name in GATED and result:
                summaries[name] = result
            print(f"# suite {name} done in {time.time()-t0:.0f}s",
                  flush=True)
        except Exception:
            failed.append(name)
            print(f"# suite {name} FAILED:", flush=True)
            traceback.print_exc()
    if failed:
        # Never update BENCH_kernels.json from a red run: a failed
        # suite's partially-emitted records would clobber the committed
        # full record set for its prefix.
        print(f"# failed suites: {failed} (baseline JSON left untouched)",
              file=sys.stderr, flush=True)
        sys.exit(1)
    if summaries:
        from benchmarks.common import write_bench_summary
        write_bench_summary(summaries)
    if args.check:
        fresh = {GATED[s]: summary for s, summary in summaries.items()}
        if not fresh:
            sys.exit("--check: no gated suite produced a summary")
        problems = check_regression(baseline, fresh,
                                    args.check_threshold,
                                    metric=args.check_metric)
        if problems:
            for p in problems:
                print(f"# PERF REGRESSION: {p}", file=sys.stderr,
                      flush=True)
            sys.exit(1)
        print("# perf check passed vs baseline", flush=True)


if __name__ == "__main__":
    main()
