"""Fig. 3: decision-boundary / stability comparison on two semicircles.

Trains the same 3-layer circuit with (a) linear neurons (LogicNets),
(b) degree-2 polynomial neurons (PolyLUT), (c) 2-layer sub-networks
(NeuraLUT, L=2 S=0 as in the paper's figure) across seeds and reports
accuracy mean/min — the paper's observation is NeuraLUT's *consistency*
(PolyLUT sometimes lands on poor solutions).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.nl_config import NeuraLUTConfig
from repro.core.train import train_neuralut
from repro.data import two_semicircles

SEEDS = (0, 1, 2)


def _cfg(kind: str) -> NeuraLUTConfig:
    return NeuraLUTConfig(
        name=f"fig3-{kind}", in_features=2, layer_widths=(8, 8, 2),
        num_classes=2, beta=4, fan_in=2, kind=kind, depth=2, width=8,
        skip=0, degree=2)


def run(epochs: int = 20) -> None:
    xtr, ytr = two_semicircles(2000, seed=100)
    xte, yte = two_semicircles(600, seed=101)
    summary = {}
    for kind in ("linear", "poly", "subnet"):
        accs = []
        t0 = time.time()
        for seed in SEEDS:
            _, _, hist = train_neuralut(_cfg(kind), xtr, ytr, xte, yte,
                                        epochs=epochs, batch=128, lr=5e-3,
                                        seed=seed)
            accs.append(hist["test_acc_q"][-1])
        dt = (time.time() - t0) / len(SEEDS)
        summary[kind] = accs
        emit(f"fig3/{kind}", dt * 1e6,
             f"acc_mean={np.mean(accs):.4f};acc_min={np.min(accs):.4f};"
             f"acc_max={np.max(accs):.4f}")
    # the paper's qualitative claims
    emit("fig3/claim_neuralut_beats_linear", 0.0,
         f"{np.mean(summary['subnet']) > np.mean(summary['linear'])}")
    emit("fig3/claim_neuralut_worstcase_ge_poly", 0.0,
         f"{np.min(summary['subnet']) >= np.min(summary['poly']) - 0.02}")


if __name__ == "__main__":
    run()
