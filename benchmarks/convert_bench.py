"""Truth-table conversion benchmark: pre-refactor per-layer converter vs
the fused device-resident sweep (core/truth_table.py), per paper
geometry.

``_legacy_convert`` vendors the pre-refactor converter: per layer it
builds a FRESH ``@jax.jit`` closure over that layer's params (so every
model converted recompiles every layer — the cost a Pareto sweep pays
per candidate), enumerates the codes on the host, and round-trips each
chunk through numpy.  The fused sweep enumerates on device, shares one
cached compiled function across layers and models of the same geometry,
and emits bit-packed tables directly.

Both converters are run on a *fresh model* of each geometry after a
warmup model, so the comparison is the steady-state per-candidate cost
in a sweep: the legacy path recompiles per model by construction, the
fused path hits its geometry cache.  Bit-exactness legacy == fused is
checked on every geometry (it is the conversion's hard invariant; the
strict fixed-seed oracle gate lives in tests/test_convert_fused.py).
The module pins XLA:CPU intra-op parallelism before jax initializes
(see ``benchmarks.common.pin_cpu_intra_op_threads``), which retires the
size-scaling ppm noise floor the comparison used to need: with the pin
in effect only a constant couple of round()-boundary flips are
tolerated (jaxlib 0.4.36's CPU runtime does not fully honor the pin
under heavy load), and without it (backend already live) the old ppm
floor applies.

    PYTHONPATH=src python -m benchmarks.convert_bench
"""
from __future__ import annotations

import pathlib
import sys
import time
from typing import Dict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import cpu_threads_pinned  # noqa: E402
from benchmarks.common import emit, pin_cpu_intra_op_threads

# Pin BEFORE jax initializes its CPU client: with one intra-op thread
# the contraction partitioning is deterministic and the legacy-vs-fused
# oracle below demands exact equality (no round()-boundary ulp flips
# under runner load, no ppm allowance).  When the pin comes too late
# (another suite already woke the backend) the ppm floor stays on.
pin_cpu_intra_op_threads()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from repro.core import lut_infer as LI
from repro.core import model as M
from repro.core import quant, subnet
from repro.core import truth_table as TT

FULL_GEOMETRIES = (
    ("neuralut_jsc_2l", "reduced"), ("neuralut_jsc_2l", "full"),
    ("neuralut_jsc_5l", "reduced"), ("neuralut_jsc_5l", "full"),
    ("neuralut_hdr_5l", "reduced"), ("neuralut_hdr_5l", "full"),
)
FAST_GEOMETRIES = (
    ("neuralut_jsc_2l", "reduced"), ("neuralut_jsc_5l", "reduced"),
    ("neuralut_hdr_5l", "reduced"), ("neuralut_jsc_5l", "full"),
)

# Sub-100K-entry geometries convert in single-digit milliseconds —
# pure dispatch noise on a busy runner.  They are still measured and
# bit-exactness-checked, but the CI gate only compares rows above the
# floor (see benchmarks/run.py _check_convert).
GATE_MIN_ENTRIES = 100_000


def _legacy_convert(cfg, params, state, statics, batch: int = 4096):
    """Pre-refactor converter, vendored (see module docstring)."""
    tables = []
    for layer_idx in range(cfg.num_layers):
        beta_in = cfg.layer_in_bits(layer_idx)
        fan_in = cfg.layer_fan_in(layer_idx)
        conn = statics[layer_idx]["conn"]
        codes = TT.enumerate_codes(beta_in, fan_in)
        t = codes.shape[0]
        src_scales = TT._input_scales(cfg, params, layer_idx)
        offs = 2 ** (beta_in - 1)
        slot_scale = jnp.asarray(src_scales)[jnp.asarray(conn)]
        lp = params["layers"][layer_idx]
        ls = state["layers"][layer_idx]

        @jax.jit
        def eval_chunk(code_chunk, lp=lp, ls=ls, slot_scale=slot_scale,
                       offs=offs, layer_idx=layer_idx):
            vals = (code_chunk[:, None, :].astype(jnp.float32) - offs) \
                * slot_scale[None]
            f = subnet.apply_hidden(cfg.kind, lp["fn"], vals,
                                    skip=cfg.skip,
                                    exps=statics[layer_idx].get("exps"))
            pre, _ = quant.bn_apply(lp["bn"], ls["bn"], f, train=False,
                                    momentum=cfg.bn_momentum)
            return quant.quant_codes(lp["quant"], pre, cfg.beta)

        b = min(batch, t)
        outs = []
        for s in range(0, t, b):
            chunk = codes[s:s + b]
            n = chunk.shape[0]
            if n < b:
                chunk = np.concatenate(
                    [chunk, np.zeros((b - n, fan_in), chunk.dtype)], axis=0)
            outs.append(np.asarray(eval_chunk(jnp.asarray(chunk)))[:n])
        tables.append(np.concatenate(outs, axis=0).T.astype(np.uint16))
    return tables


def _fresh_model(cfg, seed: int):
    statics = M.model_static(cfg)
    params, state = M.model_init(cfg, jax.random.PRNGKey(seed))
    x = jnp.asarray(
        np.random.default_rng(seed).normal(0, 1, (64, cfg.in_features)),
        jnp.float32)
    _, _, state = M.model_apply(cfg, params, state, statics, x, train=True)
    return statics, params, state


def run(fast: bool = False) -> Dict:
    import importlib
    geoms = FAST_GEOMETRIES if fast else FULL_GEOMETRIES
    out: Dict = {"fast_mode": fast, "geometries": {}}
    for config_mod, variant in geoms:
        mod = importlib.import_module(f"repro.configs.{config_mod}")
        cfg = getattr(mod, variant)()
        entries = sum(cfg.layer_widths[i] * cfg.table_size(i)
                      for i in range(cfg.num_layers))

        # Warmup model: first-candidate cost (compiles for both paths).
        statics, params, state = _fresh_model(cfg, seed=0)
        _legacy_convert(cfg, params, state, statics)
        t0 = time.perf_counter()
        TT.convert_packed(cfg, params, state, statics)
        cold_s = time.perf_counter() - t0

        # Fresh models: the steady-state per-candidate cost in a sweep.
        # Median of 3 candidates — small geometries convert in
        # milliseconds, where a single noisy sample on a busy runner
        # could trip the CI regression gate.
        legacy_ts, fused_ts = [], []
        mismatches = 0
        packed_ok = True
        for seed in (1, 2, 3):
            statics, params, state = _fresh_model(cfg, seed=seed)
            t0 = time.perf_counter()
            legacy = _legacy_convert(cfg, params, state, statics)
            legacy_ts.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            tables, packed = TT.convert_packed(cfg, params, state, statics)
            fused_ts.append(time.perf_counter() - t0)
            mismatches += sum(int((a != b).sum())
                              for a, b in zip(legacy, tables))
            packed_ok &= all(
                (LI.pack_tables(t, cfg.beta) == p).all()
                for t, p in zip(tables, packed))
        legacy_s = sorted(legacy_ts)[1]
        fused_s = sorted(fused_ts)[1]
        bit_exact = mismatches == 0
        # With intra-op threads pinned (module top) the size-scaling
        # ppm noise floor is retired for a constant two-entry
        # allowance: jaxlib 0.4.36's thunk-runtime CPU client does not
        # fully honor the pin, so a rare round()-boundary flip (~1 per
        # 3.4M entries, observed only under heavy load) can survive it.
        # Unpinned (backend woken by an earlier suite), the ppm floor
        # applies.  Anything above the allowance is a real converter
        # divergence (fail).  The strict oracle gate lives in
        # tests/test_convert_fused.py.
        allowed = 3 if cpu_threads_pinned() \
            else max(3, entries * 3 // 1_000_000)  # 3 models converted
        if not packed_ok or mismatches > allowed:
            # RuntimeError (not SystemExit) so benchmarks/run.py's
            # per-suite handler records the failure and the other
            # suites still run.
            raise RuntimeError(
                f"{cfg.name}: fused conversion diverged from the "
                f"pre-refactor converter ({mismatches}/{3 * entries} "
                f"entries over 3 models, packed_ok={packed_ok})")
        if mismatches:
            print(f"# NOTE {cfg.name}: {mismatches}/{3 * entries} "
                  f"boundary entries flipped (thread-scheduling ulp "
                  f"noise, see module docstring)", flush=True)

        row = {
            "entries": entries,
            "gate": entries >= GATE_MIN_ENTRIES,
            "legacy_s": legacy_s,
            "fused_s": fused_s,
            "fused_cold_s": cold_s,
            "entries_per_s": entries / fused_s,
            "legacy_entries_per_s": entries / legacy_s,
            "speedup": legacy_s / fused_s,
            "bit_exact": bit_exact,
            "mismatched_entries": mismatches,
        }
        out["geometries"][cfg.name] = row
        emit(f"convert/{cfg.name}", fused_s * 1e6,
             f"entries={entries};entries_per_s={row['entries_per_s']:.2e};"
             f"legacy_s={legacy_s:.3f};speedup={row['speedup']:.2f}x;"
             f"bit_exact={bit_exact}")
    return out


if __name__ == "__main__":
    from benchmarks.common import write_bench_summary
    write_bench_summary({"convert": run()})
