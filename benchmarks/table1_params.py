"""Table I: trainable-parameter scaling of the hidden function per L-LUT.

Verifies the closed forms (linear in F for NeuraLUT at fixed N,L;
polynomial in F for PolyLUT at fixed D; exponential-combinatorial in D)
against the actual parameter pytrees, and prints the scaling table.
"""
from __future__ import annotations

import math

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import subnet


def _count(spec) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(spec))


def run() -> None:
    N, L, S, D = 16, 4, 2, 2
    rows = []
    for F in (2, 3, 4, 6, 8, 12):
        logic = F + 1
        poly = math.comb(F + D, D)
        neura = subnet.param_count_formula(F, L, N, S)
        actual = _count(subnet.subnet_spec(1, F, L, N, S))
        assert actual == neura, (actual, neura)
        rows.append((F, logic, poly, neura))
        emit(f"table1/params_F{F}", 0.0,
             f"logicnets={logic};polylut_D2={poly};neuralut={neura}")
    # scaling claims: NeuraLUT linear in F — constant slope dP/dF
    fs = np.array([r[0] for r in rows], float)
    ps = np.array([r[3] for r in rows], float)
    slopes = np.diff(ps) / np.diff(fs)
    emit("table1/neuralut_linear_in_F", 0.0,
         f"slope_rel_std={float(np.std(slopes)/np.mean(slopes)):.4f}"
         f";slope={slopes[0]:.0f}/F")
    # PolyLUT grows superlinearly in F
    pol = [r[2] for r in rows]
    emit("table1/polylut_superlinear", 0.0,
         f"ratio_F12_F2={pol[-1]/pol[0]:.1f}x_vs_neuralut="
         f"{rows[-1][3]/rows[0][3]:.2f}x")


if __name__ == "__main__":
    run()
