"""LM substrate step benchmark (reduced configs, CPU wall time): train-step
and decode-step us/call for representative architectures."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.config import ShapeConfig, TrainConfig, get_config
from repro.models import api
from repro.optim.adamw import adamw_init
from repro.train.step import make_serve_step, make_train_step

ARCHS = ("llama3-8b", "deepseek-v2-lite-16b", "jamba-v0.1-52b", "xlstm-350m")


def run() -> None:
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        shape = ShapeConfig("b", "train", 64, 4)
        batch = api.make_batch(cfg, shape, jax.random.PRNGKey(1))
        batch = jax.tree.map(lambda x: x % cfg.vocab_size
                             if x.dtype == jnp.int32 else x, batch)
        step = jax.jit(make_train_step(cfg, TrainConfig(), q_chunk=32))
        opt = adamw_init(params)

        def train_once():
            p2, o2, m = step(params, opt, batch)
            jax.block_until_ready(m["loss"])

        us = time_call(train_once, warmup=2, iters=5)
        toks = shape.global_batch * shape.seq_len
        emit(f"lm_step/train_{arch}", us, f"tok_per_s={toks/us*1e6:.0f}")

        sspec = api.decode_state_spec(cfg, 4, 64)
        state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sspec,
                             is_leaf=lambda x: isinstance(
                                 x, jax.ShapeDtypeStruct))
        state["pos"] = jnp.int32(8)
        dstep = jax.jit(make_serve_step(cfg))
        tok = jnp.ones((4, 1), jnp.int32)

        def decode_once():
            logits, _ = dstep(params, state, tok)
            jax.block_until_ready(logits)

        us = time_call(decode_once, warmup=2, iters=5)
        emit(f"lm_step/decode_{arch}", us, f"tok_per_s={4/us*1e6:.0f}")


if __name__ == "__main__":
    run()
