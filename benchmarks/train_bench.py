"""Train-throughput benchmark: per-step host-sync loop vs the scanned
device-resident trainer (core/train.py), on the paper's JSC-5L model.

``_host_sync_loop`` vendors the pre-refactor training loop verbatim in
behaviour: one jitted dispatch per minibatch, a ``float(loss)`` host
sync every step, numpy permutation indexing + a fresh H2D transfer per
batch, and the canonical (B, O, F) einsum layout in the forward pass.
The scanned trainer runs the whole epoch as one compiled scan with the
data device-resident and the subnet in the fast neuron-leading layout.
The steps/s ratio is the headline "train" entry of BENCH_kernels.json,
gated by ``benchmarks/run.py --check`` (~3x on this container — 2.98x
in the committed thread-pinned baseline the CI ratio gate rides on).

The ensemble row measures the vmapped multi-seed sweep in aggregate
model-steps/s — the Pareto/multi-restart scenario the trainer exists
for (train S candidate networks in one compiled computation).

``run_kernel`` is the separate "train_kernel" section: one jitted SGD
step through the fused fwd+bwd Pallas kernel route
(``exec_plan`` route ``kernel_train``, kernels/neuralut_grad.py) vs the
same step through the neuron-leading jnp route, timed interleaved.  The
recorded ``speedup`` (kernel/jnp steps-per-s ratio) is machine-relative
and CI-gated like train/convert; on this CPU container the kernel
executes in Pallas interpret mode and the ratio documents the interpret
overhead — the win case is a compiled TPU lowering, same kernel body.

    PYTHONPATH=src python -m benchmarks.train_bench
"""
from __future__ import annotations

import pathlib
import sys
import time
from typing import Dict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import model as M
from repro.core import quant, subnet
from repro.core.train import _make_epoch_fn, _make_step_fn
from repro.data import jsc_synthetic
from repro.optim import adamw_init, adamw_update, sgdr_schedule

BATCH = 256
N_TRAIN = 4096


def _legacy_model_apply(cfg, params, state, statics, x):
    """Pre-refactor training forward: canonical einsum layout."""
    beta_in = cfg.beta_in or cfg.beta
    v = quant.quant_apply(params["in_quant"], x, beta_in)
    new_states = []
    pre = None
    for i in range(cfg.num_layers):
        conn = jnp.asarray(statics[i]["conn"])
        f = subnet.apply_hidden(cfg.kind, params["layers"][i]["fn"],
                                v[:, conn], skip=cfg.skip,
                                exps=statics[i].get("exps"),
                                batch_leading=False)
        pre, nbn = quant.bn_apply(params["layers"][i]["bn"],
                                  state["layers"][i]["bn"], f, train=True,
                                  momentum=cfg.bn_momentum)
        v = quant.quant_apply(params["layers"][i]["quant"], pre, cfg.beta)
        new_states.append({"bn": nbn})
    return pre, {"layers": new_states}


def _make_host_sync_epoch(cfg, statics, *, epochs: int, lr: float = 2e-3):
    """The old train_neuralut inner loop, as a run-one-epoch closure."""

    @jax.jit
    def step_fn(params, state, opt, xb, yb):
        def loss_fn(p):
            logits, new_state = _legacy_model_apply(cfg, p, state, statics,
                                                    xb)
            return M.ce_loss(logits, yb), new_state

        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr_t = sgdr_schedule(opt["count"], lr_max=lr, lr_min=lr * 1e-2,
                             t0=epochs * (N_TRAIN // BATCH), t_mult=2)
        params, opt = adamw_update(grads, opt, params, lr=lr_t,
                                   weight_decay=1e-4, grad_clip=1.0)
        return params, new_state, opt, loss

    rng = np.random.default_rng(0)

    def run_epoch(carry, x, y):
        params, state, opt = carry
        n = x.shape[0]
        perm = rng.permutation(n)
        for s in range(n // BATCH):
            idx = perm[s * BATCH:(s + 1) * BATCH]
            params, state, opt, loss = step_fn(
                params, state, opt, jnp.asarray(x[idx]),
                jnp.asarray(y[idx]))
            float(loss)  # the per-step host sync being measured
        return (params, state, opt)

    return run_epoch


def _measure_paired(cfg, statics, params, state, opt, x, y, *,
                    epochs: int, lr: float = 2e-3):
    """(host steps/s, scanned steps/s) from INTERLEAVED epoch timings.

    Each round times one host-sync epoch then one scanned epoch
    back-to-back, so machine load hits both paths alike and the
    recorded speedup ratio stays meaningful on a noisy runner (the
    --check-metric speedup CI gate rides on it).
    """
    n = x.shape[0]
    spe = n // BATCH
    host_epoch = _make_host_sync_epoch(cfg, statics, epochs=epochs, lr=lr)
    step = _make_step_fn(cfg, statics, lr=lr, weight_decay=1e-4,
                         t0=epochs * spe)
    epoch_fn = _make_epoch_fn(step, n, spe, BATCH)
    xd, yd = jnp.asarray(x), jnp.asarray(y)
    key = jax.random.PRNGKey(0)

    h_carry = (params, state, opt)
    s_carry = (params, state, opt)
    # warmup both (compile + steady state)
    h_carry = host_epoch(h_carry, x, y)
    out = epoch_fn(*s_carry, key, xd, yd)
    jax.block_until_ready(out)
    s_carry = out[:3]

    host_ts, scan_ts = [], []
    for ep in range(epochs):
        t0 = time.perf_counter()
        h_carry = host_epoch(h_carry, x, y)
        host_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        out = epoch_fn(*s_carry, jax.random.fold_in(key, ep), xd, yd)
        jax.block_until_ready(out)
        s_carry = out[:3]
        scan_ts.append(time.perf_counter() - t0)
    return spe / min(host_ts), spe / min(scan_ts)


def _ensemble_sweep(cfg, statics, x, y, *, seeds: int, epochs: int,
                    lr: float = 2e-3) -> float:
    """Aggregate model-steps/s of the vmapped multi-seed sweep (warm
    compiled epochs, the steady state a Pareto run spends its time in)."""
    from repro.core.train import (_make_ensemble_epoch_fn, init_ensemble)
    n = x.shape[0]
    spe = n // BATCH
    step = _make_step_fn(cfg, statics, lr=lr, weight_decay=1e-4,
                         t0=epochs * spe)
    epoch_fn = _make_ensemble_epoch_fn(step, n, spe, BATCH)
    params, state, opt, keys = init_ensemble(cfg, tuple(range(seeds)), x)
    xd, yd = jnp.asarray(x), jnp.asarray(y)

    def one_epoch(params, state, opt, ep):
        ekeys = jax.vmap(lambda k: jax.random.fold_in(k, ep))(keys)
        out = epoch_fn(params, state, opt, ekeys, xd, yd)
        jax.block_until_ready(out)
        return out[:3]

    params, state, opt = one_epoch(params, state, opt, 0)  # compile
    times = []
    for ep in range(epochs):
        t0 = time.perf_counter()
        params, state, opt = one_epoch(params, state, opt, ep + 1)
        times.append(time.perf_counter() - t0)
    return seeds * spe / min(times)


def run(fast: bool = False) -> Dict:
    from repro.configs.neuralut_jsc_5l import full
    cfg = full()
    statics = M.model_static(cfg)
    x, y = jsc_synthetic(N_TRAIN, seed=0)
    params, state = M.model_init(cfg, jax.random.PRNGKey(0))
    params = M.calibrate_in_quant(cfg, params, x)
    opt = adamw_init(params)
    # min-of-N interleaved timed epochs: N >= 2 even in smoke mode so
    # one noisy epoch on a busy runner cannot trip the CI gate.
    epochs = 2 if fast else 4

    host_sps, scan_sps = _measure_paired(cfg, statics, params, state,
                                         opt, x, y, epochs=epochs)
    emit("train/host_sync_loop", 1e6 / host_sps,
         f"steps_per_s={host_sps:.1f};batch={BATCH}")
    speedup = scan_sps / host_sps
    emit("train/scanned_epoch", 1e6 / scan_sps,
         f"steps_per_s={scan_sps:.1f};speedup={speedup:.2f}x")

    seeds = 2 if fast else 4
    ens_sps = _ensemble_sweep(cfg, statics, x, y, seeds=seeds,
                              epochs=epochs)
    emit("train/ensemble_sweep", 1e6 / ens_sps,
         f"model_steps_per_s={ens_sps:.1f};seeds={seeds};"
         f"vs_host={ens_sps / host_sps:.2f}x")

    return {
        "config": cfg.name,
        "fast_mode": fast,
        "batch": BATCH,
        "steps_per_epoch": N_TRAIN // BATCH,
        "host_sync_steps_per_s": host_sps,
        "scanned_steps_per_s": scan_sps,
        "speedup": speedup,
        "ensemble_seeds": seeds,
        "ensemble_model_steps_per_s": ens_sps,
    }


def run_kernel(fast: bool = False) -> Dict:
    """Kernel-vs-jnp training step ("train_kernel" bench section)."""
    from repro.configs.neuralut_jsc_5l import full
    from repro.core.exec_plan import plan_subnet_exec
    cfg = full()
    statics = M.model_static(cfg)
    x, y = jsc_synthetic(N_TRAIN, seed=0)
    params, state = M.model_init(cfg, jax.random.PRNGKey(0))
    params = M.calibrate_in_quant(cfg, params, x)
    opt = adamw_init(params)
    xb, yb = jnp.asarray(x[:BATCH]), jnp.asarray(y[:BATCH])

    fns = {}
    for name, route in (("jnp", "neuron_leading"),
                        ("kernel", "kernel_train")):
        step = _make_step_fn(
            cfg, statics, lr=2e-3, weight_decay=1e-4, t0=100,
            exec_plan=plan_subnet_exec(cfg, purpose="train",
                                       route=route))
        fns[name] = jax.jit(step)
        jax.block_until_ready(fns[name](params, state, opt, xb, yb))

    iters = 5 if fast else 15
    times: Dict[str, list] = {"jnp": [], "kernel": []}
    for _ in range(iters):
        # interleaved so machine load hits both routes alike — the CI
        # gate rides on the ratio, not the absolute step times
        for name in ("jnp", "kernel"):
            t0 = time.perf_counter()
            jax.block_until_ready(fns[name](params, state, opt, xb, yb))
            times[name].append(time.perf_counter() - t0)
    jnp_sps = 1.0 / min(times["jnp"])
    kernel_sps = 1.0 / min(times["kernel"])
    speedup = kernel_sps / jnp_sps
    emit("train_kernel/jnp_step", 1e6 / jnp_sps,
         f"steps_per_s={jnp_sps:.1f};batch={BATCH}")
    emit("train_kernel/kernel_step", 1e6 / kernel_sps,
         f"steps_per_s={kernel_sps:.1f};speedup={speedup:.3f}x;"
         f"backend={jax.default_backend()}")
    return {
        "config": cfg.name,
        "fast_mode": fast,
        "batch": BATCH,
        "backend": jax.default_backend(),
        "jnp_steps_per_s": jnp_sps,
        "kernel_steps_per_s": kernel_sps,
        "speedup": speedup,
    }


if __name__ == "__main__":
    from benchmarks.common import write_bench_summary
    write_bench_summary({"train": run(), "train_kernel": run_kernel()})
