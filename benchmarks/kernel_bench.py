"""Kernel-level benchmark: fused grouped-subnet + LUT-lookup + cascade paths.

Wall-clock on this CPU measures the XLA (jnp) paths; the Pallas kernels run
in interpret mode (semantics only), so their *structural* win is reported
from the HLO analyzer instead: op counts and HBM-traffic estimate of the
fused kernel vs the layer-by-layer einsum chain.

The cascade sweep compares the serving fast path (whole LUT network in ONE
dispatch, ``kernels/ref.lut_cascade_ref`` jitted end-to-end — the jnp twin
of the Pallas ``lut_cascade`` kernel) against the per-layer path (one
jitted dispatch per layer, (B, O) codes round-tripping device memory
between layers) on the JSC-5L geometry, plus the bit-packed vs unpacked
table footprint.  ``run()`` returns the cascade summary dict that
benchmarks/run.py writes to BENCH_kernels.json; ``run_cpu()`` gates the
cache-blocked ``fused_cpu_blocked`` route against a vendored copy of
the packed shift-matmul path it replaced as the CPU serving default.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.ref import grouped_subnet_ref, lut_gather_ref
from repro.roofline.hlo import analyze_hlo


def _agreement_route(backend: Optional[str]) -> str:
    """Forced cascade route for the small-tile bit-exactness record.
    ``None`` keeps the historical record (the Mosaic-TPU kernel body,
    interpret-emulated off-TPU); ``--backend`` pins another column of
    the backend matrix so any runner can exercise its lowering."""
    return {None: "fused_kernel_tpu", "tpu": "fused_kernel_tpu",
            "gpu": "fused_kernel_gpu", "cpu": "fused_cpu_blocked"}[backend]


def _jsc5l_chain_net(rng):
    """Random (cfg, tables, statics) on the full JSC-5L geometry —
    lookup cost does not depend on table contents."""
    from repro.configs.neuralut_jsc_5l import full
    cfg = full()
    statics, tables = [], []
    w_prev = cfg.in_features
    for i, o in enumerate(cfg.layer_widths):
        f = cfg.layer_fan_in(i)
        statics.append({"conn": rng.integers(0, w_prev, (o, f))})
        tables.append(rng.integers(0, 2 ** cfg.beta,
                                   (o, cfg.table_size(i))).astype(np.uint16))
        w_prev = o
    return cfg, tables, statics


def _cascade_sweep(fast: bool, backend: Optional[str] = None) -> Dict:
    """Cascade-vs-per-layer on the JSC-5L shape with random tables
    (lookup cost does not depend on table contents)."""
    from repro.core.exec_plan import plan_cascade_exec
    from repro.core.lut_infer import pack_index
    from repro.kernels.lut_cascade import build_shift_mats, cascade_tables
    from repro.kernels.ops import cascade_apply
    from repro.kernels.ref import lut_cascade_packed_ref

    rng = np.random.default_rng(0)
    cfg, tables, statics = _jsc5l_chain_net(rng)
    conns = [jnp.asarray(s["conn"]) for s in statics]
    tbls = [jnp.asarray(t.astype(np.int32)) for t in tables]
    in_bits = tuple(cfg.layer_in_bits(i) for i in range(cfg.num_layers))
    lookups = sum(cfg.layer_widths)  # per sample

    # per-layer serving path: one dispatch per layer; the (B, O) code
    # tensor leaves the device computation between every pair of layers.
    layer_fns = [
        jax.jit(lambda c, i=i: lut_gather_ref(
            tbls[i], pack_index(c[:, conns[i]], in_bits[i])))
        for i in range(cfg.num_layers)]

    def per_layer(codes):
        c = codes
        for fn in layer_fns:
            c = fn(c)
        return c

    # fused fast path: whole cascade in ONE jitted dispatch — shift-matmul
    # addresses + bit-packed table gathers (the serving engine's non-TPU
    # fused path, same algorithm as the Pallas kernel)
    packed = cascade_tables(cfg, tables)
    unpacked_bytes = sum(t.astype(np.int32).nbytes for t in tables)
    packed_bytes = sum(p.nbytes for p in packed)
    pts = [jnp.asarray(p) for p in packed]
    sms = [jnp.asarray(m) for m in build_shift_mats(cfg, statics)]
    fused = jax.jit(lambda c: lut_cascade_packed_ref(c, sms, pts, cfg.beta))

    sweep = []
    batches = (256,) if fast else (256, 1024, 4096)
    for b in batches:
        codes = jnp.asarray(
            rng.integers(0, 2 ** cfg.layer_in_bits(0),
                         (b, cfg.in_features)), jnp.int32)
        ref_out = np.asarray(per_layer(codes))
        assert (np.asarray(fused(codes)) == ref_out).all()
        us_pl = time_call(
            lambda: jax.block_until_ready(per_layer(codes)))
        us_f = time_call(lambda: fused(codes).block_until_ready())
        row = {
            "batch": b,
            "per_layer_us": round(us_pl, 1),
            "fused_us": round(us_f, 1),
            "per_layer_lookups_per_s": b * lookups / us_pl * 1e6,
            "fused_lookups_per_s": b * lookups / us_f * 1e6,
            "speedup": us_pl / us_f,
        }
        sweep.append(row)
        emit(f"kernel/cascade_b{b}", us_f,
             f"per_layer_us={us_pl:.1f};speedup={row['speedup']:.2f}x;"
             f"fused_lookups_per_s={row['fused_lookups_per_s']:.2e}")

    # Forced-route bit-exactness on a small tile (kernel routes run in
    # interpret emulation where their accelerator is absent)
    route = _agreement_route(backend)
    bsm = 16
    codes = jnp.asarray(
        rng.integers(0, 2 ** cfg.layer_in_bits(0), (bsm, cfg.in_features)),
        jnp.int32)
    plan = plan_cascade_exec(cfg, route=route, block_b=8)
    got = np.asarray(cascade_apply(codes, sms, pts, plan=plan))
    agree = bool((got == np.asarray(per_layer(codes))).all())
    emit("kernel/cascade_pallas_agreement", 0.0,
         f"bit_exact={agree};route={route};"
         f"packed_bytes={packed_bytes};"
         f"unpacked_int32_bytes={unpacked_bytes};"
         f"ratio={packed_bytes/unpacked_bytes:.4f}")

    return {
        "config": cfg.name,
        "fast_mode": fast,
        "agreement_route": route,
        "per_layer_dispatches": 3 * cfg.num_layers,
        "fused_dispatches": 1,
        "lookups_per_sample": lookups,
        "table_bytes_unpacked_int32": unpacked_bytes,
        "table_bytes_packed": packed_bytes,
        "packed_ratio": packed_bytes / unpacked_bytes,
        "pallas_cascade_bit_exact": agree,
        "sweep": sweep,
    }


def run_dag(fast: bool = False, backend: Optional[str] = None) -> Dict:
    """DAG cascade: single-launch fused walk vs per-node dispatch.

    The ``cascade`` section gates the *chain* fast path; this section
    gates the LUT-graph generalization on the PolyLUT-Add JSC-5L
    adder-tree (three arity-2 nodes + classifier).  The per-node path
    dispatches one jitted concat+gather+lookup per node — the (B, O)
    code buffers round-trip device memory between nodes — while the
    fused path walks the whole topo-sorted schedule (per-source
    shift-matmuls summed, branch codes added in registers) in ONE
    jitted dispatch, same algorithm as the Pallas DAG kernel.  Summary
    rows mirror the chain sweep so run.py's cascade checker gates both.
    """
    from repro.configs.polylut_add_jsc_5l import full
    from repro.core.exec_plan import plan_cascade_exec
    from repro.core.lut_infer import pack_index
    from repro.kernels.lut_cascade import (build_graph_shift_mats,
                                           graph_cascade_meta,
                                           graph_cascade_tables)
    from repro.kernels.ops import cascade_apply
    from repro.kernels.ref import lut_cascade_packed_ref

    cfg = full()
    rng = np.random.default_rng(0)
    statics, tables = [], []
    for i, nd in enumerate(cfg.nodes):
        pool_w = sum(cfg.buffer_width(s) for s in cfg.node_sources(i))
        statics.append({"conns": [
            rng.integers(0, pool_w, (nd.width, nd.fan_in))
            for _ in range(nd.arity)]})
        tables.append([
            rng.integers(0, 2 ** cfg.beta,
                         (nd.width, cfg.table_size(i))).astype(np.uint16)
            for _ in range(nd.arity)])
    lookups = sum(nd.width * nd.arity for nd in cfg.nodes)  # per sample

    # per-node serving path: one jitted dispatch per DAG node; source
    # buffers leave the device computation between every pair of nodes.
    node_fns = []
    for i, nd in enumerate(cfg.nodes):
        in_bits = cfg.node_in_bits(i)
        conns_i = [jnp.asarray(c) for c in statics[i]["conns"]]
        tbls_i = [jnp.asarray(t.astype(np.int32)) for t in tables[i]]

        def node_fn(*srcs, _ib=in_bits, _cs=conns_i, _ts=tbls_i):
            pool = jnp.concatenate(srcs, axis=1)
            code = None
            for c_, t_ in zip(_cs, _ts):
                d = lut_gather_ref(t_, pack_index(pool[:, c_], _ib))
                code = d if code is None else code + d
            return code

        node_fns.append(jax.jit(node_fn))
    node_srcs = [cfg.node_sources(i) for i in range(cfg.num_layers)]

    def per_node(codes):
        bufs = [codes]
        for fn, srcs in zip(node_fns, node_srcs):
            bufs.append(fn(*[bufs[s] for s in srcs]))
        return bufs[-1]

    # fused fast path: the whole DAG schedule in ONE jitted dispatch
    schedule = graph_cascade_meta(cfg)
    pts = [jnp.asarray(p) for p in graph_cascade_tables(cfg, tables)]
    sms = [jnp.asarray(m) for m in build_graph_shift_mats(cfg, statics)]
    fused = jax.jit(lambda c: lut_cascade_packed_ref(
        c, sms, pts, cfg.beta, schedule=schedule))

    sweep = []
    batches = (256,) if fast else (256, 1024, 4096)
    for b in batches:
        codes = jnp.asarray(
            rng.integers(0, 2 ** cfg.node_in_bits(0),
                         (b, cfg.in_features)), jnp.int32)
        ref_out = np.asarray(per_node(codes))
        assert (np.asarray(fused(codes)) == ref_out).all()
        us_pn = time_call(
            lambda: jax.block_until_ready(per_node(codes)))
        us_f = time_call(lambda: fused(codes).block_until_ready())
        row = {
            "batch": b,
            "per_node_us": round(us_pn, 1),
            "fused_us": round(us_f, 1),
            "per_node_lookups_per_s": b * lookups / us_pn * 1e6,
            "fused_lookups_per_s": b * lookups / us_f * 1e6,
            "speedup": us_pn / us_f,
        }
        sweep.append(row)
        emit(f"kernel_dag/cascade_dag_b{b}", us_f,
             f"per_node_us={us_pn:.1f};speedup={row['speedup']:.2f}x;"
             f"fused_lookups_per_s={row['fused_lookups_per_s']:.2e}")

    # Forced-route bit-exactness on a small tile (interpret emulation
    # where the route's accelerator is absent)
    route = _agreement_route(backend)
    bsm = 16
    codes = jnp.asarray(
        rng.integers(0, 2 ** cfg.node_in_bits(0), (bsm, cfg.in_features)),
        jnp.int32)
    plan = plan_cascade_exec(cfg, route=route, block_b=8)
    got = np.asarray(cascade_apply(codes, sms, pts, plan=plan))
    agree = bool((got == np.asarray(per_node(codes))).all())
    emit("kernel_dag/cascade_dag_pallas_agreement", 0.0,
         f"bit_exact={agree};route={route}")

    return {
        "config": cfg.name,
        "fast_mode": fast,
        "agreement_route": route,
        "per_node_dispatches": cfg.num_layers,
        "fused_dispatches": 1,
        "branches": sum(nd.arity for nd in cfg.nodes),
        "lookups_per_sample": lookups,
        "pallas_dag_bit_exact": agree,
        "sweep": sweep,
    }


def run_cpu(fast: bool = False) -> Dict:
    """Cache-blocked CPU cascade (``ref.lut_cascade_blocked``, the
    ``fused_cpu_blocked`` route) vs the bit-packed shift-matmul path it
    replaces as the off-accelerator serving default.

    The baseline is a *vendored* copy of ``lut_cascade_packed_ref`` as
    of the route's introduction, so the section keeps measuring the
    blocked path against the same yardstick even if ``kernels/ref.py``
    evolves.  The blocked path's tile size is micro-swept first and the
    winner recorded (``chosen_block_b``); the acceptance bar is
    blocked >= 1.5x packed-ref at batch 4096, so 4096 stays in the
    sweep even in ``--fast`` CI mode.  Rows mirror the ``cascade``
    schema (``batch`` / ``fused_lookups_per_s`` / ``speedup``) so
    run.py's cascade checker gates this section unchanged.
    """
    from repro.core.lut_infer import packed_slots
    from repro.kernels.lut_cascade import build_shift_mats, cascade_tables
    from repro.kernels.ref import lut_cascade_blocked

    rng = np.random.default_rng(0)
    cfg, tables, statics = _jsc5l_chain_net(rng)
    lookups = sum(cfg.layer_widths)  # per sample
    pts = [jnp.asarray(p) for p in cascade_tables(cfg, tables)]
    sms = [jnp.asarray(m) for m in build_shift_mats(cfg, statics)]

    # Vendored baseline: kernels/ref.lut_cascade_packed_ref's chain
    # walk, frozen at the blocked route's introduction.
    p = packed_slots(cfg.beta)
    slot_bits = p.bit_length() - 1
    mask = (1 << cfg.beta) - 1

    def _packed_ref_vendored(codes):
        c = codes.astype(jnp.float32)
        for sm, packed in zip(sms, pts):
            addr = jnp.dot(c, sm.astype(jnp.float32)).astype(jnp.int32)
            wsel = jax.lax.shift_right_logical(addr, slot_bits)
            slot = addr & (p - 1)
            o = packed.shape[0]
            word = packed[jnp.arange(o)[None, :], wsel]
            code = jax.lax.shift_right_logical(word, cfg.beta * slot) & mask
            c = code.astype(jnp.float32)
        return c.astype(jnp.int32)

    baseline = jax.jit(_packed_ref_vendored)

    def blocked_jit(bb):
        return jax.jit(lambda c: lut_cascade_blocked(
            c, sms, pts, cfg.beta, block_b=bb))

    # Tile-size micro-sweep at the acceptance batch; the winner serves
    # the whole batch sweep (and documents the cache-blocking choice).
    b_tune = 4096
    codes_t = jnp.asarray(
        rng.integers(0, 2 ** cfg.layer_in_bits(0),
                     (b_tune, cfg.in_features)), jnp.int32)
    candidates = (128, 256, 512, 1024)
    tile_sweep = []
    for bb in candidates:
        fn = blocked_jit(bb)
        tile_sweep.append({
            "block_b": bb,
            "us": round(time_call(
                lambda: fn(codes_t).block_until_ready()), 1)})
    chosen = min(tile_sweep, key=lambda r: r["us"])["block_b"]
    emit("kernel_cpu/blocked_tile_sweep", 0.0,
         f"chosen_block_b={chosen};" + ";".join(
             f"b{r['block_b']}_us={r['us']}" for r in tile_sweep))
    blocked = blocked_jit(chosen)

    sweep = []
    batches = (1024, 4096) if fast else (256, 1024, 4096)
    for b in batches:
        codes = jnp.asarray(
            rng.integers(0, 2 ** cfg.layer_in_bits(0),
                         (b, cfg.in_features)), jnp.int32)
        ref_out = np.asarray(baseline(codes))
        assert (np.asarray(blocked(codes)) == ref_out).all()
        us_ref = time_call(lambda: baseline(codes).block_until_ready())
        us_blk = time_call(lambda: blocked(codes).block_until_ready())
        row = {
            "batch": b,
            "packed_ref_us": round(us_ref, 1),
            "blocked_us": round(us_blk, 1),
            "packed_ref_lookups_per_s": b * lookups / us_ref * 1e6,
            "fused_lookups_per_s": b * lookups / us_blk * 1e6,
            "speedup": us_ref / us_blk,
        }
        sweep.append(row)
        emit(f"kernel_cpu/cascade_cpu_b{b}", us_blk,
             f"packed_ref_us={us_ref:.1f};speedup={row['speedup']:.2f}x;"
             f"fused_lookups_per_s={row['fused_lookups_per_s']:.2e}")

    return {
        "config": cfg.name,
        "fast_mode": fast,
        "baseline": "lut_cascade_packed_ref (vendored at blocked-route "
                    "introduction)",
        "chosen_block_b": chosen,
        "tile_sweep": tile_sweep,
        "lookups_per_sample": lookups,
        "sweep": sweep,
    }


def run(fast: bool = False, backend: Optional[str] = None) -> Optional[Dict]:
    rng = np.random.default_rng(0)
    B, NO, F, N, L, S = 1024, 256, 6, 16, 4, 2
    widths = [F] + [N] * (L - 1) + [1]
    xg = jnp.asarray(rng.normal(0, 1, (B, NO, F)), jnp.float32)
    lw = [jnp.asarray(rng.normal(0, .5, (NO, widths[i], widths[i + 1])),
                      jnp.float32) for i in range(L)]
    lb = [jnp.asarray(rng.normal(0, .1, (NO, widths[i + 1])), jnp.float32)
          for i in range(L)]
    sw = [jnp.asarray(rng.normal(0, .5, (NO, widths[c * S], widths[(c + 1) * S])),
                      jnp.float32) for c in range(L // S)]
    sb = [jnp.asarray(rng.normal(0, .1, (NO, widths[(c + 1) * S])),
                      jnp.float32) for c in range(L // S)]

    jf = jax.jit(lambda *a: grouped_subnet_ref(a[0], list(a[1:5]),
                                               list(a[5:9]), list(a[9:11]),
                                               list(a[11:13]), skip=S))
    args = [xg] + lw + lb + sw + sb
    out = jf(*args)
    us = time_call(lambda: jf(*args).block_until_ready())
    flops = 2 * B * NO * sum(widths[i] * widths[i + 1] for i in range(L))
    emit("kernel/grouped_subnet_xla", us,
         f"gflops={flops/us/1e3:.2f};B={B};NO={NO}")

    # HLO traffic: XLA einsum chain vs what the fused kernel admits
    hlo = jf.lower(*args).compile().as_text()
    ana = analyze_hlo(hlo, num_partitions=1)
    ideal = (B * NO * F + sum(NO * widths[i] * widths[i + 1]
                              for i in range(L)) + B * NO) * 4
    emit("kernel/grouped_subnet_traffic", 0.0,
         f"xla_hbm_bytes={ana.hbm_bytes:.2e};"
         f"fused_kernel_bytes={ideal:.2e};"
         f"reduction={ana.hbm_bytes/ideal:.1f}x")

    # LUT lookup path
    O2, T, B2 = 512, 4096, 4096
    tbl = jnp.asarray(rng.integers(0, 256, (O2, T)), jnp.int32)
    addr = jnp.asarray(rng.integers(0, T, (B2, O2)), jnp.int32)
    jg = jax.jit(lut_gather_ref)
    jg(tbl, addr).block_until_ready()
    us = time_call(lambda: jg(tbl, addr).block_until_ready())
    emit("kernel/lut_lookup_xla", us,
         f"lookups_per_s={B2*O2/us*1e6:.2e}")

    # Pallas kernels: correctness already covered by tests; record the
    # interpret-mode agreement as the bench artifact
    from repro.kernels.ops import grouped_subnet_op, lut_lookup_op
    ok1 = np.allclose(np.asarray(grouped_subnet_op(
        xg[:128], lw, lb, sw, sb, skip=S, block_b=64, block_o=32)),
        np.asarray(grouped_subnet_ref(xg[:128], lw, lb, sw, sb, skip=S)),
        rtol=2e-5, atol=2e-5)
    ok2 = bool((np.asarray(lut_lookup_op(tbl, addr[:16], block_b=8,
                                         block_o=64))
                == np.asarray(lut_gather_ref(tbl, addr[:16]))).all())
    emit("kernel/pallas_interpret_agreement", 0.0,
         f"grouped_subnet={ok1};lut_lookup={ok2}")

    # Fused LUT-cascade serving fast path (the summary feeds
    # BENCH_kernels.json — the repo's kernel perf trajectory)
    return _cascade_sweep(fast, backend=backend)


if __name__ == "__main__":
    from benchmarks.common import write_bench_summary
    write_bench_summary({"kernel": run(), "kernel_dag": run_dag(),
                         "kernel_cpu": run_cpu()})
