"""Kernel-level benchmark: fused grouped-subnet + LUT-lookup paths.

Wall-clock on this CPU measures the XLA (jnp) paths; the Pallas kernels run
in interpret mode (semantics only), so their *structural* win is reported
from the HLO analyzer instead: op counts and HBM-traffic estimate of the
fused kernel vs the layer-by-layer einsum chain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.ref import grouped_subnet_ref, lut_gather_ref
from repro.roofline.hlo import analyze_hlo


def run() -> None:
    rng = np.random.default_rng(0)
    B, O, F, N, L, S = 1024, 256, 6, 16, 4, 2
    widths = [F] + [N] * (L - 1) + [1]
    xg = jnp.asarray(rng.normal(0, 1, (B, O, F)), jnp.float32)
    lw = [jnp.asarray(rng.normal(0, .5, (O, widths[i], widths[i + 1])),
                      jnp.float32) for i in range(L)]
    lb = [jnp.asarray(rng.normal(0, .1, (O, widths[i + 1])), jnp.float32)
          for i in range(L)]
    sw = [jnp.asarray(rng.normal(0, .5, (O, widths[c * S], widths[(c + 1) * S])),
                      jnp.float32) for c in range(L // S)]
    sb = [jnp.asarray(rng.normal(0, .1, (O, widths[(c + 1) * S])),
                      jnp.float32) for c in range(L // S)]

    jf = jax.jit(lambda *a: grouped_subnet_ref(a[0], list(a[1:5]),
                                               list(a[5:9]), list(a[9:11]),
                                               list(a[11:13]), skip=S))
    args = [xg] + lw + lb + sw + sb
    out = jf(*args)
    us = time_call(lambda: jf(*args).block_until_ready())
    flops = 2 * B * O * sum(widths[i] * widths[i + 1] for i in range(L))
    emit("kernel/grouped_subnet_xla", us,
         f"gflops={flops/us/1e3:.2f};B={B};O={O}")

    # HLO traffic: XLA einsum chain vs what the fused kernel admits
    hlo = jf.lower(*args).compile().as_text()
    ana = analyze_hlo(hlo, num_partitions=1)
    ideal = (B * O * F + sum(O * widths[i] * widths[i + 1]
                             for i in range(L)) + B * O) * 4
    emit("kernel/grouped_subnet_traffic", 0.0,
         f"xla_hbm_bytes={ana.hbm_bytes:.2e};"
         f"fused_kernel_bytes={ideal:.2e};"
         f"reduction={ana.hbm_bytes/ideal:.1f}x")

    # LUT lookup path
    O2, T, B2 = 512, 4096, 4096
    tbl = jnp.asarray(rng.integers(0, 256, (O2, T)), jnp.int32)
    addr = jnp.asarray(rng.integers(0, T, (B2, O2)), jnp.int32)
    jg = jax.jit(lut_gather_ref)
    jg(tbl, addr).block_until_ready()
    us = time_call(lambda: jg(tbl, addr).block_until_ready())
    emit("kernel/lut_lookup_xla", us,
         f"lookups_per_s={B2*O2/us*1e6:.2e}")

    # Pallas kernels: correctness already covered by tests; record the
    # interpret-mode agreement as the bench artifact
    from repro.kernels.ops import grouped_subnet_op, lut_lookup_op
    ok1 = np.allclose(np.asarray(grouped_subnet_op(
        xg[:128], lw, lb, sw, sb, skip=S, block_b=64, block_o=32)),
        np.asarray(grouped_subnet_ref(xg[:128], lw, lb, sw, sb, skip=S)),
        rtol=2e-5, atol=2e-5)
    ok2 = bool((np.asarray(lut_lookup_op(tbl, addr[:16], block_b=8,
                                         block_o=64))
                == np.asarray(lut_gather_ref(tbl, addr[:16]))).all())
    emit("kernel/pallas_interpret_agreement", 0.0,
         f"grouped_subnet={ok1};lut_lookup={ok2}")


if __name__ == "__main__":
    run()
